//! Pattern-guided parallel DFS exploration (paper §4.1).
//!
//! Executes a [`MatchingPlan`] against the input graph. Each input
//! vertex roots an independent task; tasks flow through the
//! work-stealing, locality-sharded scheduler ([`crate::exec::sched`],
//! the paper's work-stealing strategy): workers drain their own shard's
//! root ranges LIFO from per-worker deques, steal FIFO when empty, and
//! — uniquely to this engine — answer starvation by *splitting the
//! current root*: the untraversed suffix of the level-1 candidate set
//! is published as a [`Task::Split`](crate::exec::sched::Task::Split)
//! and re-entered here with a
//! candidate-position window, so one hub root no longer serializes a
//! run's tail. `MinerConfig::with_steal(false)` or `SANDSLASH_NO_STEAL=1`
//! pins the run to the seed global-cursor loop, the scheduling oracle.
//! Within a task a thread explores its subtree depth-first,
//! maintaining:
//!
//! * the embedding stack with MEC connectivity codes,
//! * the extension state for the selected mode (below),
//! * symmetry-breaking / non-adjacency / degree constraints from the plan.
//!
//! Three extension modes:
//!
//! * **Set-centric** (`opts.sets`, the default): each level's candidate
//!   set is computed once with the adaptive kernels in
//!   [`crate::graph::setops`] — the intersection of the adjacency lists
//!   named by `adj_mask`, minus the lists in `nonadj_mask`, with the
//!   symmetry-breaking partial orders fused into the seed list as range
//!   bounds. Buffers are per-thread and per-level, so the hot path does
//!   no allocation; high-degree roots additionally publish their
//!   neighborhood as a bitmap probed in O(1) per candidate — and when
//!   the seed list is itself dense, the level intersects bitset×bitset
//!   with the word-parallel kernels instead
//!   ([`DENSE_FRONTIER_WORD_FACTOR`], §PR-3).
//! * **Local-graph** (`opts.lg`, layered on the set-centric mode; paper
//!   §5 "LG"): once the search passes the plan's coverage level
//!   (`MatchingPlan::lg_level`) and the matched prefix's neighborhoods
//!   are small enough (`LG_UNIVERSE_CAP`), the remaining levels run on
//!   a [`crate::engine::local_graph::PlanLocalGraph`]: candidates come
//!   from degeneracy-bounded local lists shrunk kClist-style at cone
//!   levels, and every plan constraint — adjacency, anti-adjacency,
//!   symmetry range bounds — resolves against local ids. The
//!   set-centric path is the differential oracle for this stage.
//! * **Scalar** (`opts.sets` off): the seed behaviour — scan the pivot's
//!   neighbor list and test every candidate against each constraint,
//!   via the MNC connectivity index when `opts.mnc`. Kept both as the
//!   differential-testing oracle and as the emulation substrate for the
//!   probe-based systems of Tables 5–9.
//!
//! Matches are delivered to a caller-supplied leaf visitor through the
//! per-thread accumulator, merged once at the end — no synchronization on
//! the hot path.

use crate::exec::sched::WorkerCtx;
use crate::exec::split::{self, SplitDriver, Splittable};
use crate::graph::{setops, CsrGraph, VertexId};
use crate::pattern::matching_order::{LevelPlan, MatchingPlan};
use crate::util::bitset::BitSet;
use crate::util::metrics::SearchStats;

use crate::obs::trace as qtrace;

use super::budget::{self, Governor, MineError, Outcome};
use super::hooks::LowLevelApi;
use super::local_graph::PlanLocalGraph;
use super::mnc::Connectivity;
use super::opts::MinerConfig;

/// Root degree at which materializing the root's neighborhood as a
/// bitmap pays for itself: the build costs O(deg(root)) once, and every
/// later level replaces a merge against that (large) list with O(1)
/// probes per surviving candidate (crossover in EXPERIMENTS.md).
const ROOT_BITSET_MIN_DEGREE: usize = 256;

/// Crossover for the local-graph stage (`opts.lg`): switch from global
/// set intersections to a shrinking local graph once the estimated
/// local universe — the summed degrees of the matched vertices whose
/// neighborhoods seed it (`LevelPlan::lg_pre_mask`) — drops to this
/// size. Building the local adjacency costs roughly one bounded
/// intersection per universe member, so it must be amortized over the
/// remaining levels; past ~2k members the build cost exceeds what the
/// degeneracy-bounded deep intersections save on the graphs we target
/// (heuristic recorded in EXPERIMENTS.md §PR-2).
const LG_UNIVERSE_CAP: usize = 2048;

/// The LG switch needs at least this many unmatched levels: with only
/// one level left, the local graph would be built and immediately
/// discarded after a single candidate sweep that the global kernels do
/// just as fast.
const LG_MIN_REMAINING: usize = 2;

/// Dense bitset×bitset frontier crossover (EXPERIMENTS.md §PR-3): with
/// the root bitmap built, replace "copy seed list, probe each element
/// against the bitmap" by "publish the seed as a second bitmap, AND
/// word-parallel, decode survivors" once the bounded seed list reaches
/// `(|V| / 64) * DENSE_FRONTIER_WORD_FACTOR` elements. The AND costs
/// |V|/64 word ops regardless of seed length, the probe filter one
/// dependent load per seed element; 4 covers the seed-bitmap build on
/// top of break-even.
const DENSE_FRONTIER_WORD_FACTOR: usize = 4;

/// LG dense-scan crossover (EXPERIMENTS.md §PR-3): scan the bounded
/// embedding-adjacency mask range with the word-parallel mask kernel
/// instead of copying the shortest source list when the local-id range
/// is at most this factor longer than that list — the vectorized scan
/// retires ~8 mask tests per cycle where the copy path pays one
/// copy + scalar mask test per seed element.
const LG_DENSE_SCAN_FACTOR: usize = 8;

/// Per-thread, per-level candidate-set buffers — the set-centric
/// frontier. All storage is reused across root tasks: zero allocation on
/// the hot path once warm.
struct Frontier {
    /// `bufs[level]` holds the materialized candidate set while that
    /// level's subtree is explored.
    bufs: Vec<Vec<VertexId>>,
    /// Ping-pong scratch shared across levels (returned before recursing).
    scratch: Vec<VertexId>,
    /// High-degree root's neighborhood bitmap (lazily sized to |V|).
    root_bits: BitSet,
    root_bits_built: bool,
    /// Scratch bitmap for the dense bitset×bitset frontier mode: the
    /// bounded seed list is published here, ANDed word-parallel against
    /// `root_bits`, and sparse-cleared before returning (§PR-3).
    cand_bits: BitSet,
}

impl Frontier {
    fn new(k: usize) -> Self {
        Self {
            bufs: vec![Vec::new(); k],
            scratch: Vec::new(),
            root_bits: BitSet::default(),
            root_bits_built: false,
            cand_bits: BitSet::default(),
        }
    }

    fn ensure_bits(&mut self, n: usize) {
        if self.root_bits.capacity() < n {
            self.root_bits = BitSet::new(n);
        }
    }

    fn ensure_cand_bits(&mut self, n: usize) {
        if self.cand_bits.capacity() < n {
            self.cand_bits = BitSet::new(n);
        }
    }
}

/// Per-thread mining state.
struct ThreadState<A> {
    acc: A,
    stats: SearchStats,
    emb: Vec<VertexId>,
    conn: Connectivity,
    front: Frontier,
    /// Shrinking local graph for the `opts.lg` stage (storage reused
    /// across root tasks).
    lg: PlanLocalGraph,
}

/// Collapse a level's symmetry-breaking partial orders to one exclusive
/// range: `cand > max(emb[j], j in gt_mask)` and `cand < min(emb[j],
/// j in lt_mask)`. Shared by the set-centric and local-graph paths.
#[inline]
fn sb_range(lp: &LevelPlan, emb: &[VertexId]) -> (Option<VertexId>, Option<VertexId>) {
    let mut lo: Option<VertexId> = None;
    let mut hi: Option<VertexId> = None;
    let mut m = lp.gt_mask;
    while m != 0 {
        let j = m.trailing_zeros() as usize;
        m &= m - 1;
        let b = emb[j];
        if lo.map_or(true, |l| b > l) {
            lo = Some(b);
        }
    }
    let mut m = lp.lt_mask;
    while m != 0 {
        let j = m.trailing_zeros() as usize;
        m &= m - 1;
        let b = emb[j];
        if hi.map_or(true, |h| b < h) {
            hi = Some(b);
        }
    }
    (lo, hi)
}

/// Mine all embeddings of `plan` in `g`; `leaf` is invoked with the
/// matched vertex tuple (in plan order). Returns the merged accumulator
/// and search statistics as a governed [`Outcome`] (PR 6): a run that
/// trips its [`Budget`](super::Budget) comes back with
/// `complete == false` and the counts accumulated before the trip; a
/// worker panic comes back as [`MineError::WorkerPanicked`] with the
/// process intact.
pub fn mine<A: Send, H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
    init: impl Fn() -> A + Sync,
    leaf: impl Fn(&mut A, &[VertexId]) + Sync,
    mut merge: impl FnMut(A, A) -> A,
) -> Result<Outcome<A>, MineError> {
    let n = g.num_vertices();
    let k = plan.size();
    let use_sets = cfg.opts.sets && k > 2;
    let use_mnc = !use_sets && cfg.opts.mnc && k > 2;
    // the root bitmap only pays off if some level past the first
    // extension constrains against the root's neighborhood AND takes the
    // materialized path (single-source levels never probe the bitmap)
    let needs_root_bits = use_sets
        && plan.levels.iter().skip(2).any(|l| {
            (l.adj_mask | l.nonadj_mask) & 1 != 0
                && (l.adj_mask.count_ones() > 1 || l.nonadj_mask != 0)
        });
    let pol = cfg.sched_policy();
    let engine = DfsEngine {
        g,
        plan,
        cfg,
        hooks,
        leaf: &leaf,
        use_sets,
        use_mnc,
        needs_root_bits,
        _acc: std::marker::PhantomData,
    };
    let gov = budget::governance_enabled().then(|| Governor::new(&cfg.budget));
    let result = split::reduce(
        n,
        &pol,
        &engine,
        gov.as_ref(),
        || ThreadState {
            acc: init(),
            stats: SearchStats::default(),
            emb: Vec::with_capacity(k),
            conn: Connectivity::new(),
            front: Frontier::new(k),
            lg: PlanLocalGraph::new(),
        },
        |a, b| {
            let mut stats = a.stats;
            stats.merge(&b.stats);
            ThreadState {
                acc: merge(a.acc, b.acc),
                stats,
                emb: a.emb,
                conn: a.conn,
                front: a.front,
                lg: a.lg,
            }
        },
    );
    match gov {
        Some(g) => g.finish(result.acc, result.stats, "dfs"),
        None => Ok(Outcome::complete(result.acc, result.stats)),
    }
}

/// The DFS engine as a [`Splittable`] root task: the level-1 sequence
/// is the root's (deterministic) candidate-position order, exactly what
/// [`visit_windowed`] walks. Whole roots arrive with `window = None`;
/// published suffixes re-enter [`mine_root`] with a position window.
struct DfsEngine<'e, A, H, L> {
    g: &'e CsrGraph,
    plan: &'e MatchingPlan,
    cfg: &'e MinerConfig,
    hooks: &'e H,
    leaf: &'e L,
    use_sets: bool,
    use_mnc: bool,
    needs_root_bits: bool,
    _acc: std::marker::PhantomData<fn() -> A>,
}

impl<A, H, L> Splittable for DfsEngine<'_, A, H, L>
where
    A: Send,
    H: LowLevelApi,
    L: Fn(&mut A, &[VertexId]) + Sync,
{
    type Acc = ThreadState<A>;

    fn mine_root(
        &self,
        st: &mut ThreadState<A>,
        ctx: &WorkerCtx<'_>,
        root: usize,
        window: Option<(usize, usize)>,
    ) {
        mine_root(
            self.g,
            self.plan,
            self.cfg,
            self.hooks,
            st,
            ctx,
            root as VertexId,
            window,
            self.use_sets,
            self.use_mnc,
            self.needs_root_bits,
            self.leaf,
        );
    }
}

/// One root task — or, for a
/// [`Task::Split`](crate::exec::sched::Task::Split), one published level-1
/// candidate window of it (set-centric runs only, the sole publisher).
/// The level-0 setup (root bitmap, MNC seed) is worker-local and
/// deterministic, so a split re-runs it and lands on exactly the
/// candidate sequence its publisher was iterating.
fn mine_root<A, H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut ThreadState<A>,
    ctx: &WorkerCtx<'_>,
    v: VertexId,
    window: Option<(usize, usize)>,
    use_sets: bool,
    use_mnc: bool,
    needs_root_bits: bool,
    leaf: &(impl Fn(&mut A, &[VertexId]) + Sync),
) {
    debug_assert!(window.is_none() || use_sets, "only set-centric roots publish splits");
    let n = g.num_vertices();
    let k = plan.size();
    let lvl0 = &plan.levels[0];
    if cfg.opts.df && g.degree(v) < lvl0.degree {
        st.stats.pruned += cfg.opts.stats as u64;
        return;
    }
    if lvl0.label != 0 && g.label(v) != lvl0.label {
        return;
    }
    st.emb.clear();
    st.emb.push(v);
    // a split root was already counted by the task that published it
    if cfg.opts.stats && window.is_none() {
        st.stats.enumerated += 1;
    }
    if k == 1 {
        leaf(&mut st.acc, &st.emb);
        return;
    }
    if use_mnc {
        st.conn.begin_root(n, g.degree(v));
        for &u in g.neighbors(v) {
            st.conn.or_insert(u, 1);
        }
    }
    let built_bits = needs_root_bits && g.degree(v) >= ROOT_BITSET_MIN_DEGREE;
    if built_bits {
        st.front.ensure_bits(n);
        for &u in g.neighbors(v) {
            st.front.root_bits.insert(u as usize);
        }
        st.front.root_bits_built = true;
    }
    if use_sets {
        extend_set(g, plan, cfg, hooks, st, 1, Some((ctx, window)), leaf);
    } else {
        extend(g, plan, cfg, hooks, st, 1, use_mnc, leaf);
    }
    if built_bits {
        st.front.root_bits.clear();
        st.front.root_bits_built = false;
    }
    if use_mnc {
        // symmetric pop: O(deg) instead of O(capacity) clear
        for &u in g.neighbors(v) {
            st.conn.and_remove(u, 1);
        }
    }
}

/// Set-centric extension: materialize the candidate set for `level` with
/// the adaptive kernels, then visit each survivor.
///
/// `l1` is present exactly at level 1 (the root's first extension): it
/// carries the scheduler handle plus the optional candidate-*position*
/// window over this level's (deterministic) candidate sequence.
/// Whole-root tasks run with no window; a
/// [`Task::Split`](crate::exec::sched::Task::Split) re-enters with the
/// published suffix. Between candidates the loop (a
/// [`SplitDriver`], shared with the ESU and FSM engines since PR 5)
/// polls [`WorkerCtx::split_requested`] and, when a worker is starving,
/// hands off its own remaining suffix — recursive splits included, so
/// hub candidates fan out until the chain is bounded by single subtrees
/// (`exec::split` module docs).
fn extend_set<A, H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut ThreadState<A>,
    level: usize,
    l1: Option<(&WorkerCtx<'_>, Option<(usize, usize)>)>,
    leaf: &(impl Fn(&mut A, &[VertexId]) + Sync),
) {
    let _span = qtrace::LevelSpan::enter(level);
    let lp = &plan.levels[level];
    // Local-graph stage (opts.lg): from the plan's coverage level on,
    // the neighborhoods of the matched prefix contain every future
    // candidate. Once they are small enough (crossover heuristic, see
    // EXPERIMENTS.md §PR-2), build a shrinking local graph and run the
    // rest of this subtree on degeneracy-bounded local lists.
    if cfg.opts.lg
        && level >= plan.lg_level
        && plan.size() - level >= LG_MIN_REMAINING
    {
        let mut est = 0usize;
        let mut m = lp.lg_pre_mask;
        while m != 0 {
            let j = m.trailing_zeros() as usize;
            m &= m - 1;
            est += g.degree(st.emb[j]);
        }
        if est <= LG_UNIVERSE_CAP {
            // The LG stage ignores `l1` safely: this branch is a
            // deterministic function of (root, plan, cfg), so a split
            // task's publisher — which by construction reached the
            // candidate loops below instead — proves the executor
            // cannot land here with a partial window; whole-root tasks
            // carry the full window, which changes nothing.
            qtrace::on_lg_root();
            extend_lg_root(g, plan, cfg, hooks, st, level, leaf);
            return;
        }
    }
    if !hooks.to_extend(&st.emb, lp.pivot) {
        return;
    }
    // Symmetry-breaking partial orders collapse to one exclusive range,
    // fused into the seed list below, so out-of-range candidates are
    // never materialized.
    let (lo, hi) = sb_range(lp, &st.emb);
    if let (Some(l), Some(h)) = (lo, hi) {
        if l + 1 >= h {
            return; // empty range
        }
    }

    if lp.adj_mask.count_ones() == 1 && lp.nonadj_mask == 0 {
        // Single adjacency source and no anti-constraints: iterate the
        // bounded slice of the pivot's list in place, no copy.
        let nbrs = g.neighbors(st.emb[lp.pivot]);
        let s = lo.map_or(0, |l| nbrs.partition_point(|&x| x <= l));
        let e = hi.map_or(nbrs.len(), |h| nbrs.partition_point(|&x| x < h));
        visit_windowed(g, plan, cfg, hooks, st, level, l1, e - s, |pos| nbrs[s + pos], leaf);
        return;
    }

    // Materialized frontier: seed from the shortest adjacency list
    // (bounds fused), then shrink with intersections / differences.
    let mut cur = std::mem::take(&mut st.front.bufs[level]);
    let mut tmp = std::mem::take(&mut st.front.scratch);
    cur.clear();
    // gather adjacency sources; the root's (usually largest) list is
    // replaced by an O(|cur|) bitmap filter when its bitmap is built
    let mut srcs = [(0u32, 0 as VertexId); 32];
    let mut ns = 0usize;
    let mut root_filter = false;
    let mut m = lp.adj_mask;
    while m != 0 {
        let j = m.trailing_zeros() as usize;
        m &= m - 1;
        if j == 0 && st.front.root_bits_built {
            root_filter = true;
            continue;
        }
        let u = st.emb[j];
        srcs[ns] = (g.degree(u) as u32, u);
        ns += 1;
    }
    if ns == 0 {
        // adjacency is the root alone: seed from its list after all
        root_filter = false;
        let u = st.emb[0];
        srcs[0] = (g.degree(u) as u32, u);
        ns = 1;
    }
    srcs[..ns].sort_unstable();
    let first = g.neighbors(srcs[0].1);
    let s = lo.map_or(0, |l| first.partition_point(|&x| x <= l));
    let e = hi.map_or(first.len(), |h| first.partition_point(|&x| x < h));
    let n_verts = g.num_vertices();
    if root_filter && (e - s) >= (n_verts / 64) * DENSE_FRONTIER_WORD_FACTOR {
        // Dense bitset×bitset frontier (§PR-3): both operands are a
        // sizable fraction of |V|, so publish the bounded seed as a
        // second bitmap and AND it against the root bitmap
        // word-parallel; survivors decode in ascending order, exactly
        // the list the probe filter would have produced.
        st.front.ensure_cand_bits(n_verts);
        for &u in &first[s..e] {
            st.front.cand_bits.insert(u as usize);
        }
        if cfg.opts.stats {
            st.stats.intersections += 1;
        }
        setops::and_words_into(
            st.front.cand_bits.words(),
            st.front.root_bits.words(),
            &mut cur,
        );
        st.front.cand_bits.clear();
    } else {
        cur.extend_from_slice(&first[s..e]);
        if root_filter && !cur.is_empty() {
            if cfg.opts.stats {
                st.stats.intersections += 1;
            }
            setops::retain_in_bitset(&mut cur, &st.front.root_bits);
        }
    }
    for i in 1..ns {
        if cur.is_empty() {
            break;
        }
        if cfg.opts.stats {
            st.stats.intersections += 1;
        }
        tmp.clear();
        setops::intersect_into(&cur, g.neighbors(srcs[i].1), &mut tmp);
        std::mem::swap(&mut cur, &mut tmp);
    }
    // non-adjacency (vertex-induced) constraints: anti-intersections
    let mut m = lp.nonadj_mask;
    while m != 0 && !cur.is_empty() {
        let j = m.trailing_zeros() as usize;
        m &= m - 1;
        if cfg.opts.stats {
            st.stats.intersections += 1;
        }
        if j == 0 && st.front.root_bits_built {
            setops::retain_not_in_bitset(&mut cur, &st.front.root_bits);
        } else {
            tmp.clear();
            setops::difference_into(&cur, g.neighbors(st.emb[j]), &mut tmp);
            std::mem::swap(&mut cur, &mut tmp);
        }
    }
    // scratch must be back in place before recursing (deeper levels
    // reuse it); bufs[level] stays checked out while we iterate
    st.front.scratch = tmp;
    visit_windowed(g, plan, cfg, hooks, st, level, l1, cur.len(), |pos| cur[pos], leaf);
    st.front.bufs[level] = cur;
}

/// Visit the candidate positions `0..len` of one set-centric level —
/// clamped to the `l1` window and polling the split protocol between
/// candidates when `l1` is present — through `get(pos)`, the path's
/// candidate accessor. One implementation for both the bounded
/// in-place and the materialized-frontier level-1 loops, so the two
/// paths cannot drift (same rationale as [`admit_candidate`]); the
/// window + publish + truncate discipline itself lives in the shared
/// [`SplitDriver`] (PR 5), so it cannot drift across *engines* either.
#[inline]
fn visit_windowed<A, H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut ThreadState<A>,
    level: usize,
    l1: Option<(&WorkerCtx<'_>, Option<(usize, usize)>)>,
    len: usize,
    get: impl Fn(usize) -> VertexId,
    leaf: &(impl Fn(&mut A, &[VertexId]) + Sync),
) {
    match l1 {
        Some((ctx, window)) => {
            let root = st.emb[0] as usize;
            for pos in SplitDriver::new(ctx, root, len, window) {
                visit_candidate(g, plan, cfg, hooks, st, level, get(pos), leaf);
            }
        }
        None => {
            for pos in 0..len {
                visit_candidate(g, plan, cfg, hooks, st, level, get(pos), leaf);
            }
        }
    }
}

/// Residual per-candidate filters shared by the set-centric and
/// local-graph paths: degree bound (DF), label, injectivity, and the
/// low-level `to_add` hook — one implementation so the two paths
/// cannot drift. Returns true when the candidate survives.
#[inline]
fn admit_candidate<A, H: LowLevelApi>(
    g: &CsrGraph,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut ThreadState<A>,
    lp: &LevelPlan,
    level: usize,
    cand: VertexId,
) -> bool {
    if cfg.opts.df && g.degree(cand) < lp.degree {
        st.stats.pruned += cfg.opts.stats as u64;
        return false;
    }
    if lp.label != 0 && g.label(cand) != lp.label {
        return false;
    }
    if st.emb.contains(&cand) {
        return false;
    }
    if !hooks.to_add(g, &st.emb, cand, level) {
        st.stats.pruned += cfg.opts.stats as u64;
        return false;
    }
    true
}

/// Shared per-candidate tail of the set-centric path: residual filters
/// (DF, label, injectivity, FP hook), then match or recurse.
#[inline]
fn visit_candidate<A, H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut ThreadState<A>,
    level: usize,
    cand: VertexId,
    leaf: &(impl Fn(&mut A, &[VertexId]) + Sync),
) {
    let k = plan.size();
    let lp = &plan.levels[level];
    if !admit_candidate(g, cfg, hooks, st, lp, level, cand) {
        return;
    }
    if level + 1 == k {
        st.emb.push(cand);
        if cfg.opts.stats {
            st.stats.enumerated += 1;
            st.stats.matches += 1;
        }
        leaf(&mut st.acc, &st.emb);
        st.emb.pop();
        return;
    }
    st.emb.push(cand);
    if cfg.opts.stats {
        st.stats.enumerated += 1;
    }
    extend_set(g, plan, cfg, hooks, st, level + 1, None, leaf);
    st.emb.pop();
}

/// Entry point of the local-graph stage: build the local universe for
/// the current partial embedding, then run every remaining level on it.
fn extend_lg_root<A, H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut ThreadState<A>,
    level: usize,
    leaf: &(impl Fn(&mut A, &[VertexId]) + Sync),
) {
    let lp = &plan.levels[level];
    let n = st.lg.init(g, &st.emb, lp.lg_pre_mask, lp.lg_touch_mask, plan.size());
    if cfg.opts.stats {
        st.stats.lg_vertices += n as u64;
    }
    if n == 0 {
        return;
    }
    extend_lg(g, plan, cfg, hooks, st, level, leaf);
}

/// Local-graph extension for one level: translate the symmetry bounds
/// into a local-id range once, materialize the smallest source list
/// (bounded), then admit each candidate with a single O(1) test of its
/// embedding-adjacency bitmask against the level's adjacency and
/// anti-adjacency masks — the local-space realization of the paper's
/// Listing-4 search, generalized to arbitrary plans.
fn extend_lg<A, H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut ThreadState<A>,
    level: usize,
    leaf: &(impl Fn(&mut A, &[VertexId]) + Sync),
) {
    let _span = qtrace::LevelSpan::enter(level);
    let k = plan.size();
    let lp = &plan.levels[level];
    if !hooks.to_extend(&st.emb, lp.pivot) {
        return;
    }
    let (lo, hi) = sb_range(lp, &st.emb);
    if let (Some(l), Some(h)) = (lo, hi) {
        if l + 1 >= h {
            return; // empty range
        }
    }
    let (lo_l, hi_l) = st.lg.local_range(lo, hi);
    if lo_l >= hi_l {
        return;
    }
    // seed from the smallest source list (pre-LG candidate list or a
    // chosen vertex's shrunken adjacency prefix)
    let mut seed = usize::MAX;
    let mut best = usize::MAX;
    let mut m = lp.adj_mask;
    while m != 0 {
        let j = m.trailing_zeros() as usize;
        m &= m - 1;
        let len = st.lg.source_len(j);
        if len < best {
            best = len;
            seed = j;
        }
    }
    debug_assert!(seed != usize::MAX, "level has no adjacency source");
    let mut buf = std::mem::take(&mut st.front.bufs[level]);
    buf.clear();
    let span = (hi_l - lo_l) as usize;
    // the `seed` guard keeps a (plan-invariant-violating) source-less
    // level loud in release builds too: it falls through to
    // `copy_source(usize::MAX, ..)` and panics instead of silently
    // enumerating the whole range with `want == 0`
    if seed != usize::MAX && span <= best.saturating_mul(LG_DENSE_SCAN_FACTOR) {
        // Dense mask scan (§PR-3): the embedding-adjacency masks alone
        // decide membership (a mask-passing vertex is in every
        // adjacency source's list by construction — see
        // `PlanLocalGraph::collect_candidates`), so sweep the bounded
        // mask range word-parallel instead of copying the seed list.
        // Everything appended here passes the mask test below.
        st.lg.collect_candidates(lo_l, hi_l, lp.adj_mask, lp.nonadj_mask, &mut buf);
    } else {
        st.lg.copy_source(seed, lo_l, hi_l, &mut buf);
    }
    if cfg.opts.stats {
        st.stats.intersections += 1;
    }
    for idx in 0..buf.len() {
        let u = buf[idx] as usize;
        let ea = st.lg.embadj(u);
        if ea & lp.adj_mask != lp.adj_mask || ea & lp.nonadj_mask != 0 {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        let cand = st.lg.global(u);
        if !admit_candidate(g, cfg, hooks, st, lp, level, cand) {
            continue;
        }
        if level + 1 == k {
            st.emb.push(cand);
            if cfg.opts.stats {
                st.stats.enumerated += 1;
                st.stats.matches += 1;
            }
            leaf(&mut st.acc, &st.emb);
            st.emb.pop();
            continue;
        }
        st.emb.push(cand);
        if cfg.opts.stats {
            st.stats.enumerated += 1;
        }
        st.lg.push(u, lp.lg_cone);
        extend_lg(g, plan, cfg, hooks, st, level + 1, leaf);
        st.lg.pop();
        st.emb.pop();
    }
    st.front.bufs[level] = buf;
}

/// Scalar extension (the seed path): scan the pivot's neighbor list and
/// test every candidate against each constraint individually.
fn extend<A, H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut ThreadState<A>,
    level: usize,
    use_mnc: bool,
    leaf: &(impl Fn(&mut A, &[VertexId]) + Sync),
) {
    let _span = qtrace::LevelSpan::enter(level);
    let k = plan.size();
    let lp = &plan.levels[level];
    let pivot_v = st.emb[lp.pivot];
    if !hooks.to_extend(&st.emb, lp.pivot) {
        return;
    }
    // Dense-MNC prefilter (§PR-3): for hub roots the connectivity codes
    // live in a flat table, so the whole pivot row is mask-filtered in
    // one gathered kernel pass before the per-candidate filters run —
    // the same survivors the per-candidate `conn.get` test admits, in
    // the same order, so only where pruning is *counted* moves.
    let dense_conn = use_mnc && st.conn.is_dense() && (lp.adj_mask | lp.nonadj_mask) != 0;
    let prefiltered = if dense_conn {
        let mut buf = std::mem::take(&mut st.front.bufs[level]);
        buf.clear();
        st.conn
            .filter_into(g.neighbors(pivot_v), lp.adj_mask, lp.nonadj_mask, &mut buf);
        if cfg.opts.stats {
            st.stats.pruned += (g.degree(pivot_v) - buf.len()) as u64;
        }
        Some(buf)
    } else {
        None
    };
    let n_cands = prefiltered.as_ref().map_or(g.degree(pivot_v), Vec::len);
    // Candidates: neighborhood of the pivot's match (or its
    // connectivity-filtered subset). Borrow juggling: neighbors()
    // borrows g (not st), and the prefilter buffer is read by index,
    // so iterating while mutating st is fine.
    for idx in 0..n_cands {
        let cand = match &prefiltered {
            Some(buf) => buf[idx],
            None => g.neighbors(pivot_v)[idx],
        };
        // degree filter (DF)
        if cfg.opts.df && g.degree(cand) < lp.degree {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        if lp.label != 0 && g.label(cand) != lp.label {
            continue;
        }
        if st.emb.contains(&cand) {
            continue;
        }
        // symmetry-breaking partial orders
        let mut ok = true;
        let mut m = lp.gt_mask;
        while m != 0 {
            let j = m.trailing_zeros() as usize;
            m &= m - 1;
            if cand <= st.emb[j] {
                ok = false;
                break;
            }
        }
        if ok {
            let mut m = lp.lt_mask;
            while m != 0 {
                let j = m.trailing_zeros() as usize;
                m &= m - 1;
                if cand >= st.emb[j] {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        // connectivity constraints (already applied by the dense-MNC
        // prefilter when it ran)
        let conn_ok = if dense_conn {
            true
        } else if use_mnc {
            let code = st.conn.get(cand);
            (code & lp.adj_mask) == lp.adj_mask && (code & lp.nonadj_mask) == 0
        } else {
            let mut good = true;
            let mut m = lp.adj_mask & !(1 << lp.pivot);
            while m != 0 {
                let j = m.trailing_zeros() as usize;
                m &= m - 1;
                if cfg.opts.stats {
                    st.stats.intersections += 1;
                }
                if !g.has_edge(cand, st.emb[j]) {
                    good = false;
                    break;
                }
            }
            if good {
                let mut m = lp.nonadj_mask;
                while m != 0 {
                    let j = m.trailing_zeros() as usize;
                    m &= m - 1;
                    if g.has_edge(cand, st.emb[j]) {
                        good = false;
                        break;
                    }
                }
            }
            good
        };
        if !conn_ok {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        if !hooks.to_add(g, &st.emb, cand, level) {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        // match at this level
        if level + 1 == k {
            st.emb.push(cand);
            if cfg.opts.stats {
                st.stats.enumerated += 1;
                st.stats.matches += 1;
            }
            leaf(&mut st.acc, &st.emb);
            st.emb.pop();
            continue;
        }
        // push, update MNC, recurse, pop
        st.emb.push(cand);
        if cfg.opts.stats {
            st.stats.enumerated += 1;
        }
        let bit = 1u32 << level;
        if use_mnc {
            for &u in g.neighbors(cand) {
                st.conn.or_insert(u, bit);
            }
        }
        extend(g, plan, cfg, hooks, st, level + 1, use_mnc, leaf);
        if use_mnc {
            for &u in g.neighbors(cand) {
                st.conn.and_remove(u, bit);
            }
        }
        st.emb.pop();
    }
    if let Some(buf) = prefiltered {
        st.front.bufs[level] = buf;
    }
}

/// Count embeddings of a plan (the common case). Same governed return
/// contract as [`mine`].
pub fn count<H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
) -> Result<Outcome<u64>, MineError> {
    mine(
        g,
        plan,
        cfg,
        hooks,
        || 0u64,
        |acc, _| *acc += 1,
        |a, b| a + b,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::hooks::NoHooks;
    use crate::engine::opts::OptFlags;
    use crate::graph::gen;
    use crate::pattern::{library, plan};

    fn cfg(opts: OptFlags) -> MinerConfig {
        MinerConfig::custom(2, 8, opts)
    }

    #[test]
    fn triangles_in_k4() {
        let g = gen::complete(4);
        let pl = plan(&library::triangle(), true, true);
        let (c, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        assert_eq!(c, 4); // C(4,3)
    }

    #[test]
    fn wedges_in_star() {
        // star with 4 leaves: C(4,2) = 6 induced wedges
        let mut b = crate::graph::builder::GraphBuilder::new(5);
        for v in 1..5 {
            b.add_edge(0, v);
        }
        let g = b.build();
        let pl = plan(&library::wedge(), true, true);
        let (c, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        assert_eq!(c, 6);
    }

    #[test]
    fn induced_vs_noninduced_wedge() {
        // triangle graph: 0 induced wedges, 3 non-induced wedge embeddings
        let g = gen::complete(3);
        let induced = plan(&library::wedge(), true, true);
        let (ci, _) = count(&g, &induced, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        assert_eq!(ci, 0);
        let noninduced = plan(&library::wedge(), false, true);
        let (cn, _) = count(&g, &noninduced, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        assert_eq!(cn, 3);
    }

    #[test]
    fn diamonds_in_k4_and_ring() {
        let pl = plan(&library::diamond(), false, true); // edge-induced (SL)
        let (c, _) = count(&gen::complete(4), &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        assert_eq!(c, 6); // K4 contains 6 non-induced diamonds
        let (r, _) = count(&gen::ring(8), &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        assert_eq!(r, 0);
    }

    #[test]
    fn four_cycles_in_ring() {
        let pl = plan(&library::cycle(4), false, true);
        let (c, _) = count(&gen::ring(4), &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        assert_eq!(c, 1);
        let (c8, _) = count(&gen::ring(8), &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        assert_eq!(c8, 0);
    }

    #[test]
    fn mnc_on_off_agree() {
        let g = gen::rmat(8, 6, 17, &[]);
        for pat in [library::diamond(), library::cycle(4), library::clique(4)] {
            let pl = plan(&pat, true, true);
            // exercise the scalar path: MNC on vs off must agree
            let mut with = cfg(OptFlags::hi());
            with.opts.sets = false;
            let mut without = with;
            without.opts.mnc = false;
            let (a, _) = count(&g, &pl, &with, &NoHooks).unwrap().into_parts();
            let (b, _) = count(&g, &pl, &without, &NoHooks).unwrap().into_parts();
            assert_eq!(a, b, "pattern {pat}");
            // and the default set-centric path must match both
            let (s, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
            assert_eq!(s, a, "set-centric vs scalar, pattern {pat}");
        }
    }

    #[test]
    fn set_and_scalar_paths_agree() {
        let g = gen::rmat(8, 6, 29, &[]);
        for vertex_induced in [true, false] {
            for pat in [
                library::triangle(),
                library::wedge(),
                library::diamond(),
                library::cycle(4),
                library::clique(4),
            ] {
                let pl = plan(&pat, vertex_induced, true);
                let (s, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
                let mut scalar = cfg(OptFlags::hi());
                scalar.opts.sets = false;
                let (c, _) = count(&g, &pl, &scalar, &NoHooks).unwrap().into_parts();
                assert_eq!(s, c, "pattern {pat} induced={vertex_induced}");
            }
        }
    }

    #[test]
    fn no_sb_counts_automorphic_copies() {
        let g = gen::rmat(7, 4, 23, &[]);
        let tri = library::triangle();
        let with_sb = plan(&tri, true, true);
        let without_sb = plan(&tri, true, false);
        let (a, _) = count(&g, &with_sb, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        let (b, _) = count(&g, &without_sb, &cfg(OptFlags::automine_like()), &NoHooks).unwrap().into_parts();
        assert_eq!(b, a * 6, "no-SB must count every automorphism");
    }

    #[test]
    fn thread_counts_equal() {
        let g = gen::rmat(8, 8, 31, &[]);
        let pl = plan(&library::clique(4), true, true);
        let (c1, _) = count(&g, &pl, &MinerConfig::single_thread(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        let (c4, _) = count(&g, &pl, &MinerConfig::custom(4, 16, OptFlags::hi()), &NoHooks).unwrap().into_parts();
        assert_eq!(c1, c4);
    }

    #[test]
    fn stats_count_matches() {
        let g = gen::rmat(7, 5, 3, &[]);
        let pl = plan(&library::triangle(), true, true);
        let mut c = cfg(OptFlags::hi().with_stats());
        c.threads = 1;
        let (count_, stats) = count(&g, &pl, &c, &NoHooks).unwrap().into_parts();
        assert_eq!(count_, stats.matches);
        assert!(stats.enumerated >= stats.matches);
    }

    #[test]
    fn fp_hook_prunes() {
        struct NoOdd;
        impl LowLevelApi for NoOdd {
            fn to_add(&self, _g: &CsrGraph, _e: &[VertexId], u: VertexId, _l: usize) -> bool {
                u % 2 == 0
            }
        }
        let g = gen::complete(6);
        let pl = plan(&library::triangle(), true, true);
        let (all, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        let (even, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoOdd).unwrap().into_parts();
        assert_eq!(all, 20); // C(6,3)
        // triangles whose level-1 and level-2 vertices are even; root free:
        // still fewer than all
        assert!(even < all && even > 0);
    }

    #[test]
    fn lg_mode_agrees_with_set_centric_across_patterns() {
        let g = gen::rmat(8, 6, 41, &[]);
        for vertex_induced in [true, false] {
            for pat in [
                library::triangle(),
                library::wedge(),
                library::diamond(),
                library::cycle(4),
                library::cycle(5),
                library::clique(4),
                library::clique(5),
                library::tailed_triangle(),
            ] {
                let pl = plan(&pat, vertex_induced, true);
                let (s, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
                let (l, _) = count(&g, &pl, &cfg(OptFlags::lo()), &NoHooks).unwrap().into_parts();
                assert_eq!(s, l, "pattern {pat} induced={vertex_induced}");
            }
        }
    }

    #[test]
    fn lg_mode_respects_fp_hook() {
        struct NoOdd;
        impl LowLevelApi for NoOdd {
            fn to_add(&self, _g: &CsrGraph, _e: &[VertexId], u: VertexId, _l: usize) -> bool {
                u % 2 == 0
            }
        }
        let g = gen::rmat(7, 6, 19, &[]);
        for pat in [library::diamond(), library::cycle(4)] {
            let pl = plan(&pat, true, true);
            let (s, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoOdd).unwrap().into_parts();
            let (l, _) = count(&g, &pl, &cfg(OptFlags::lo()), &NoOdd).unwrap().into_parts();
            assert_eq!(s, l, "pattern {pat}");
        }
    }

    #[test]
    fn lg_mode_thread_invariant() {
        let g = gen::rmat(9, 7, 23, &[]);
        let pl = plan(&library::diamond(), true, true);
        let c1 = MinerConfig::single_thread(OptFlags::lo());
        let c4 = MinerConfig::custom(4, 16, OptFlags::lo());
        let (a, _) = count(&g, &pl, &c1, &NoHooks).unwrap().into_parts();
        let (b, _) = count(&g, &pl, &c4, &NoHooks).unwrap().into_parts();
        assert_eq!(a, b);
    }

    #[test]
    fn lg_reports_universe_stats() {
        let g = gen::rmat(8, 8, 3, &[]);
        let pl = plan(&library::clique(4), true, true);
        let mut c = cfg(OptFlags::lo().with_stats());
        c.threads = 1;
        let (hi_count, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
        let (lo_count, stats) = count(&g, &pl, &c, &NoHooks).unwrap().into_parts();
        assert_eq!(hi_count, lo_count);
        // cliques pass the coverage level at 1, so LG fires on this
        // small graph and the universe counter moves
        assert!(stats.lg_vertices > 0);
    }

    #[test]
    fn dense_frontier_and_dense_mnc_agree_on_two_hub_graph() {
        // both hubs adjacent to every vertex: the root bitmap is built,
        // the bounded seed lists are a large fraction of |V| (the
        // word-parallel bitset×bitset path fires), and hub roots push
        // the scalar path into dense-MNC gather mode
        let n = 640usize;
        let mut b = crate::graph::builder::GraphBuilder::new(n);
        for v in 2..n as u32 {
            b.add_edge(0, v);
            b.add_edge(1, v);
            // a sparse ring among the leaves so deeper levels survive
            let w = if v + 1 < n as u32 { v + 1 } else { 2 };
            b.add_edge(v, w);
        }
        b.add_edge(0, 1);
        let g = b.build();
        crate::util::metrics::dispatch::set_enabled(true);
        let before = crate::util::metrics::dispatch::snapshot();
        for pat in [
            library::triangle(),
            library::cycle(4),
            library::diamond(),
            library::clique(4),
        ] {
            for vertex_induced in [true, false] {
                let pl = plan(&pat, vertex_induced, true);
                let (s, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
                let mut scalar = cfg(OptFlags::hi());
                scalar.opts.sets = false;
                let (c, _) = count(&g, &pl, &scalar, &NoHooks).unwrap().into_parts();
                assert_eq!(s, c, "pattern {pat} induced={vertex_induced}");
                let mut probe = scalar;
                probe.opts.mnc = false;
                let (p, _) = count(&g, &pl, &probe, &NoHooks).unwrap().into_parts();
                assert_eq!(s, p, "probe path, pattern {pat} induced={vertex_induced}");
            }
        }
        let after = crate::util::metrics::dispatch::snapshot();
        // the word-parallel dense frontier must actually have run
        assert!(
            after.word_parallel > before.word_parallel,
            "dense bitset×bitset frontier never dispatched"
        );
        assert!(
            after.gather_filter > before.gather_filter,
            "dense-MNC gathered prefilter never dispatched"
        );
    }

    #[test]
    fn stealing_and_cursor_oracle_agree_on_skewed_graphs() {
        // counts must be invariant under the scheduler swap, including
        // the hub graphs whose level-1 sets actually get split; counter
        // assertions (splits really fire) live in
        // tests/sched_invariance.rs where the binary controls timing
        let g = crate::graph::gen::two_hub(1 << 10);
        for pat in [library::triangle(), library::clique(4), library::cycle(4)] {
            for vertex_induced in [true, false] {
                let pl = plan(&pat, vertex_induced, true);
                let oracle_cfg = MinerConfig::custom(4, 1, OptFlags::hi()).with_steal(false);
                let (want, _) = count(&g, &pl, &oracle_cfg, &NoHooks).unwrap().into_parts();
                for shards in [1usize, 2] {
                    let steal_cfg =
                        MinerConfig::custom(4, 1, OptFlags::hi()).with_shards(shards);
                    let (got, _) = count(&g, &pl, &steal_cfg, &NoHooks).unwrap().into_parts();
                    assert_eq!(
                        got, want,
                        "pattern {pat} induced={vertex_induced} shards={shards}"
                    );
                }
            }
        }
    }

    #[test]
    fn root_bitmap_mode_agrees_on_hub_graph() {
        // star-core graph: hub degree far above ROOT_BITSET_MIN_DEGREE so
        // roots exercise the bitmap filter path
        let hub_deg = super::ROOT_BITSET_MIN_DEGREE * 2;
        let mut b = crate::graph::builder::GraphBuilder::new(hub_deg + 2);
        for v in 2..(hub_deg + 2) as u32 {
            b.add_edge(0, v);
            b.add_edge(1, v);
        }
        b.add_edge(0, 1);
        let g = b.build();
        for pat in [library::triangle(), library::cycle(4), library::diamond()] {
            let pl = plan(&pat, true, true);
            let (s, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks).unwrap().into_parts();
            let mut scalar = cfg(OptFlags::hi());
            scalar.opts.sets = false;
            let (c, _) = count(&g, &pl, &scalar, &NoHooks).unwrap().into_parts();
            assert_eq!(s, c, "pattern {pat}");
        }
    }
}
