//! Pattern-guided parallel DFS exploration (paper §4.1).
//!
//! Executes a [`MatchingPlan`] against the input graph. Each input
//! vertex roots an independent task; tasks are claimed dynamically by
//! worker threads (the paper's work-stealing strategy). Within a task a
//! thread explores its subtree depth-first, maintaining:
//!
//! * the embedding stack with MEC connectivity codes,
//! * the MNC connectivity map (when `opts.mnc`),
//! * symmetry-breaking / non-adjacency / degree constraints from the plan.
//!
//! Matches are delivered to a caller-supplied leaf visitor through the
//! per-thread accumulator, merged once at the end — no synchronization on
//! the hot path.

use crate::graph::{CsrGraph, VertexId};
use crate::pattern::matching_order::MatchingPlan;
use crate::util::metrics::SearchStats;
use crate::util::pool::parallel_reduce;

use super::hooks::LowLevelApi;
use super::mnc::ConnectivityMap;
use super::opts::MinerConfig;

/// Per-thread mining state.
struct ThreadState<A> {
    acc: A,
    stats: SearchStats,
    emb: Vec<VertexId>,
    map: ConnectivityMap,
}

/// Mine all embeddings of `plan` in `g`; `leaf` is invoked with the
/// matched vertex tuple (in plan order). Returns the merged accumulator
/// and search statistics.
pub fn mine<A: Send, H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
    init: impl Fn() -> A + Sync,
    leaf: impl Fn(&mut A, &[VertexId]) + Sync,
    mut merge: impl FnMut(A, A) -> A,
) -> (A, SearchStats) {
    let n = g.num_vertices();
    let k = plan.size();
    let use_mnc = cfg.opts.mnc && k > 2;
    let lvl0 = &plan.levels[0];

    let (acc, stats) = {
        let result = parallel_reduce(
            n,
            cfg.threads,
            cfg.chunk,
            || ThreadState {
                acc: init(),
                stats: SearchStats::default(),
                emb: Vec::with_capacity(k),
                map: ConnectivityMap::with_capacity(1024),
            },
            |st, v| {
                let v = v as VertexId;
                if cfg.opts.df && g.degree(v) < lvl0.degree {
                    st.stats.pruned += cfg.opts.stats as u64;
                    return;
                }
                if lvl0.label != 0 && g.label(v) != lvl0.label {
                    return;
                }
                st.emb.clear();
                st.emb.push(v);
                if cfg.opts.stats {
                    st.stats.enumerated += 1;
                }
                if k == 1 {
                    leaf(&mut st.acc, &st.emb);
                    return;
                }
                if use_mnc {
                    for &u in g.neighbors(v) {
                        st.map.or_insert(u, 1);
                    }
                }
                extend(g, plan, cfg, hooks, st, 1, use_mnc, &leaf);
                if use_mnc {
                    // symmetric pop: O(deg) instead of O(capacity) clear
                    for &u in g.neighbors(v) {
                        st.map.and_remove(u, 1);
                    }
                }
            },
            |a, b| {
                let mut stats = a.stats;
                stats.merge(&b.stats);
                ThreadState { acc: merge(a.acc, b.acc), stats, emb: a.emb, map: a.map }
            },
        );
        (result.acc, result.stats)
    };
    (acc, stats)
}

fn extend<A, H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut ThreadState<A>,
    level: usize,
    use_mnc: bool,
    leaf: &(impl Fn(&mut A, &[VertexId]) + Sync),
) {
    let k = plan.size();
    let lp = &plan.levels[level];
    let pivot_v = st.emb[lp.pivot];
    if !hooks.to_extend(&st.emb, lp.pivot) {
        return;
    }
    // Candidates: neighborhood of the pivot's match. Borrow juggling:
    // neighbors() borrows g (not st), so iterating while mutating st is
    // fine.
    for idx in 0..g.degree(pivot_v) {
        let cand = g.neighbors(pivot_v)[idx];
        // degree filter (DF)
        if cfg.opts.df && g.degree(cand) < lp.degree {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        if lp.label != 0 && g.label(cand) != lp.label {
            continue;
        }
        if st.emb.contains(&cand) {
            continue;
        }
        // symmetry-breaking partial orders
        let mut ok = true;
        let mut m = lp.gt_mask;
        while m != 0 {
            let j = m.trailing_zeros() as usize;
            m &= m - 1;
            if cand <= st.emb[j] {
                ok = false;
                break;
            }
        }
        if ok {
            let mut m = lp.lt_mask;
            while m != 0 {
                let j = m.trailing_zeros() as usize;
                m &= m - 1;
                if cand >= st.emb[j] {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        // connectivity constraints
        let conn_ok = if use_mnc {
            let code = st.map.get(cand);
            (code & lp.adj_mask) == lp.adj_mask && (code & lp.nonadj_mask) == 0
        } else {
            let mut good = true;
            let mut m = lp.adj_mask & !(1 << lp.pivot);
            while m != 0 {
                let j = m.trailing_zeros() as usize;
                m &= m - 1;
                if cfg.opts.stats {
                    st.stats.intersections += 1;
                }
                if !g.has_edge(cand, st.emb[j]) {
                    good = false;
                    break;
                }
            }
            if good {
                let mut m = lp.nonadj_mask;
                while m != 0 {
                    let j = m.trailing_zeros() as usize;
                    m &= m - 1;
                    if g.has_edge(cand, st.emb[j]) {
                        good = false;
                        break;
                    }
                }
            }
            good
        };
        if !conn_ok {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        if !hooks.to_add(g, &st.emb, cand, level) {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        // match at this level
        if level + 1 == k {
            st.emb.push(cand);
            if cfg.opts.stats {
                st.stats.enumerated += 1;
                st.stats.matches += 1;
            }
            leaf(&mut st.acc, &st.emb);
            st.emb.pop();
            continue;
        }
        // push, update MNC, recurse, pop
        st.emb.push(cand);
        if cfg.opts.stats {
            st.stats.enumerated += 1;
        }
        let bit = 1u32 << level;
        if use_mnc {
            for &u in g.neighbors(cand) {
                st.map.or_insert(u, bit);
            }
        }
        extend(g, plan, cfg, hooks, st, level + 1, use_mnc, leaf);
        if use_mnc {
            for &u in g.neighbors(cand) {
                st.map.and_remove(u, bit);
            }
        }
        st.emb.pop();
    }
}

/// Count embeddings of a plan (the common case).
pub fn count<H: LowLevelApi>(
    g: &CsrGraph,
    plan: &MatchingPlan,
    cfg: &MinerConfig,
    hooks: &H,
) -> (u64, SearchStats) {
    mine(
        g,
        plan,
        cfg,
        hooks,
        || 0u64,
        |acc, _| *acc += 1,
        |a, b| a + b,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::hooks::NoHooks;
    use crate::engine::opts::OptFlags;
    use crate::graph::gen;
    use crate::pattern::{library, plan};

    fn cfg(opts: OptFlags) -> MinerConfig {
        MinerConfig { threads: 2, chunk: 8, opts }
    }

    #[test]
    fn triangles_in_k4() {
        let g = gen::complete(4);
        let pl = plan(&library::triangle(), true, true);
        let (c, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks);
        assert_eq!(c, 4); // C(4,3)
    }

    #[test]
    fn wedges_in_star() {
        // star with 4 leaves: C(4,2) = 6 induced wedges
        let g = gen::complete(2); // placeholder replaced below
        let _ = g;
        let mut b = crate::graph::builder::GraphBuilder::new(5);
        for v in 1..5 {
            b.add_edge(0, v);
        }
        let g = b.build();
        let pl = plan(&library::wedge(), true, true);
        let (c, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks);
        assert_eq!(c, 6);
    }

    #[test]
    fn induced_vs_noninduced_wedge() {
        // triangle graph: 0 induced wedges, 3 non-induced wedge embeddings
        let g = gen::complete(3);
        let induced = plan(&library::wedge(), true, true);
        let (ci, _) = count(&g, &induced, &cfg(OptFlags::hi()), &NoHooks);
        assert_eq!(ci, 0);
        let noninduced = plan(&library::wedge(), false, true);
        let (cn, _) = count(&g, &noninduced, &cfg(OptFlags::hi()), &NoHooks);
        assert_eq!(cn, 3);
    }

    #[test]
    fn diamonds_in_k4_and_ring() {
        let pl = plan(&library::diamond(), false, true); // edge-induced (SL)
        let (c, _) = count(&gen::complete(4), &pl, &cfg(OptFlags::hi()), &NoHooks);
        assert_eq!(c, 6); // K4 contains 6 non-induced diamonds
        let (r, _) = count(&gen::ring(8), &pl, &cfg(OptFlags::hi()), &NoHooks);
        assert_eq!(r, 0);
    }

    #[test]
    fn four_cycles_in_ring() {
        let pl = plan(&library::cycle(4), false, true);
        let (c, _) = count(&gen::ring(4), &pl, &cfg(OptFlags::hi()), &NoHooks);
        assert_eq!(c, 1);
        let (c8, _) = count(&gen::ring(8), &pl, &cfg(OptFlags::hi()), &NoHooks);
        assert_eq!(c8, 0);
    }

    #[test]
    fn mnc_on_off_agree() {
        let g = gen::rmat(8, 6, 17, &[]);
        for pat in [library::diamond(), library::cycle(4), library::clique(4)] {
            let pl = plan(&pat, true, true);
            let with = cfg(OptFlags::hi());
            let mut without = cfg(OptFlags::hi());
            without.opts.mnc = false;
            let (a, _) = count(&g, &pl, &with, &NoHooks);
            let (b, _) = count(&g, &pl, &without, &NoHooks);
            assert_eq!(a, b, "pattern {pat}");
        }
    }

    #[test]
    fn no_sb_counts_automorphic_copies() {
        let g = gen::rmat(7, 4, 23, &[]);
        let tri = library::triangle();
        let with_sb = plan(&tri, true, true);
        let without_sb = plan(&tri, true, false);
        let (a, _) = count(&g, &with_sb, &cfg(OptFlags::hi()), &NoHooks);
        let (b, _) = count(&g, &without_sb, &cfg(OptFlags::automine_like()), &NoHooks);
        assert_eq!(b, a * 6, "no-SB must count every automorphism");
    }

    #[test]
    fn thread_counts_equal() {
        let g = gen::rmat(8, 8, 31, &[]);
        let pl = plan(&library::clique(4), true, true);
        let (c1, _) = count(&g, &pl, &MinerConfig { threads: 1, chunk: usize::MAX, opts: OptFlags::hi() }, &NoHooks);
        let (c4, _) = count(&g, &pl, &MinerConfig { threads: 4, chunk: 16, opts: OptFlags::hi() }, &NoHooks);
        assert_eq!(c1, c4);
    }

    #[test]
    fn stats_count_matches() {
        let g = gen::rmat(7, 5, 3, &[]);
        let pl = plan(&library::triangle(), true, true);
        let mut c = cfg(OptFlags::hi().with_stats());
        c.threads = 1;
        let (count_, stats) = count(&g, &pl, &c, &NoHooks);
        assert_eq!(count_, stats.matches);
        assert!(stats.enumerated >= stats.matches);
    }

    #[test]
    fn fp_hook_prunes() {
        struct NoOdd;
        impl LowLevelApi for NoOdd {
            fn to_add(&self, _g: &CsrGraph, _e: &[VertexId], u: VertexId, _l: usize) -> bool {
                u % 2 == 0
            }
        }
        let g = gen::complete(6);
        let pl = plan(&library::triangle(), true, true);
        let (all, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoHooks);
        let (even, _) = count(&g, &pl, &cfg(OptFlags::hi()), &NoOdd);
        assert_eq!(all, 20); // C(6,3)
        // triangles whose level-1 and level-2 vertices are even; root free:
        // still fewer than all
        assert!(even < all && even > 0);
    }
}
