//! Frequent Subgraph Mining engine (paper §4.1 "pattern filtering" +
//! §6.2 k-FSM).
//!
//! Implements the paper's strategy: DFS on the *sub-pattern tree* (not
//! the subgraph tree), gSpan-style. Each sub-pattern owns its bin of
//! embeddings (vertex mappings); extension grows every embedding by one
//! edge (edge-induced), children are binned by canonical labeled pattern
//! code, each child pattern is expanded from exactly one canonical
//! parent (duplicate pattern enumeration check), and MNI domain support
//! prunes infrequent sub-patterns before their embeddings are ever
//! generated — the anti-monotone filtering that BFS systems do level by
//! level, done here per-thread without synchronization.

use std::collections::{HashMap, HashSet};

use crate::graph::{CsrGraph, VertexId};
use crate::pattern::{canonical_code, CanonCode, Pattern};
use crate::util::metrics::SearchStats;
use crate::util::pool::parallel_reduce;

use super::support::DomainSupport;

#[derive(Clone, Debug)]
/// One frequent pattern with its support evidence.
pub struct FrequentPattern {
    /// The pattern graph.
    pub pattern: Pattern,
    /// Canonical code (dedup key).
    pub code: CanonCode,
    /// Domain (MNI) support.
    pub support: u64,
    /// Number of edge-induced embeddings found.
    pub embeddings: u64,
}

#[derive(Debug, Default)]
/// Output of an FSM run.
pub struct FsmResult {
    /// Frequent patterns, sorted by canonical code.
    pub frequent: Vec<FrequentPattern>,
    /// Search counters.
    pub stats: SearchStats,
}

/// Mine all frequent edge-induced patterns with at most `max_edges`
/// edges and MNI support > `min_support`.
pub fn mine_fsm(
    g: &CsrGraph,
    max_edges: usize,
    min_support: u64,
    threads: usize,
) -> FsmResult {
    assert!(g.is_labeled(), "FSM requires a vertex-labeled graph");
    // ---- roots: single-edge patterns, binned by labeled code ----
    struct Root {
        pattern: Pattern,
        code: CanonCode,
        embeddings: Vec<Vec<VertexId>>,
    }
    let mut roots: HashMap<CanonCode, Root> = HashMap::new();
    for (u, v) in g.edges() {
        let (lu, lv) = (g.label(u), g.label(v));
        let mut p = Pattern::from_edges(&[(0, 1)]);
        // canonical orientation: position 0 takes the smaller label
        let (a, b) = if lu <= lv { (u, v) } else { (v, u) };
        p.set_label(0, g.label(a));
        p.set_label(1, g.label(b));
        let code = canonical_code(&p);
        let entry = roots.entry(code.clone()).or_insert_with(|| Root {
            pattern: p,
            code,
            embeddings: Vec::new(),
        });
        entry.embeddings.push(vec![a, b]);
        // symmetric mapping also valid when labels equal (needed for
        // correct MNI domains)
        if g.label(a) == g.label(b) {
            entry.embeddings.push(vec![b, a]);
        }
    }
    let mut root_list: Vec<Root> = roots.into_values().collect();
    // deterministic order for reproducibility
    root_list.sort_by(|a, b| a.code.cmp(&b.code));
    // frequency-filter roots
    root_list.retain(|r| {
        let mut d = DomainSupport::new(2);
        for m in &r.embeddings {
            d.add(m);
        }
        d.support() > min_support
    });

    // ---- parallel DFS over root sub-pattern trees ----
    let out = parallel_reduce(
        root_list.len(),
        threads,
        1,
        FsmResult::default,
        |acc, i| {
            let r = &root_list[i];
            let mut d = DomainSupport::new(2);
            for m in &r.embeddings {
                d.add(m);
            }
            acc.frequent.push(FrequentPattern {
                pattern: r.pattern.clone(),
                code: r.code.clone(),
                support: d.support(),
                embeddings: r.embeddings.len() as u64,
            });
            if max_edges > 1 {
                extend_pattern(
                    g,
                    &r.pattern,
                    &r.embeddings,
                    max_edges,
                    min_support,
                    acc,
                );
            }
        },
        |mut a, b| {
            a.frequent.extend(b.frequent);
            a.stats.merge(&b.stats);
            a
        },
    );
    let mut out = out;
    // deterministic output order
    out.frequent.sort_by(|a, b| a.code.cmp(&b.code));
    out
}

/// One child of a sub-pattern-tree node, ready for support evaluation.
pub struct ChildNode {
    /// Canonical code (dedup key).
    pub code: CanonCode,
    /// The pattern graph.
    pub pattern: Pattern,
    /// Embeddings carried down the sub-pattern tree.
    pub embeddings: Vec<Vec<VertexId>>,
    /// Domain (MNI) support.
    pub support: u64,
}

/// Expand one sub-pattern node: generate all one-edge child extensions of
/// all embeddings, bin by child pattern code, keep frequent canonical
/// children, recurse.
fn extend_pattern(
    g: &CsrGraph,
    pattern: &Pattern,
    embeddings: &[Vec<VertexId>],
    max_edges: usize,
    min_support: u64,
    acc: &mut FsmResult,
) {
    for child in expand_children(g, pattern, embeddings, min_support, &mut acc.stats) {
        acc.frequent.push(FrequentPattern {
            pattern: child.pattern.clone(),
            code: child.code,
            support: child.support,
            embeddings: child.embeddings.len() as u64,
        });
        if child.pattern.num_edges() < max_edges {
            extend_pattern(g, &child.pattern, &child.embeddings, max_edges, min_support, acc);
        }
    }
}

/// One level of sub-pattern-tree expansion: all frequent canonical
/// children of (`pattern`, `embeddings`). Shared by the DFS engine above
/// and the BFS engine (`mine_fsm_bfs`) used for system emulation.
pub fn expand_children(
    g: &CsrGraph,
    pattern: &Pattern,
    embeddings: &[Vec<VertexId>],
    min_support: u64,
    stats: &mut SearchStats,
) -> Vec<ChildNode> {
    let p_verts = pattern.num_vertices();
    let parent_code = canonical_code(pattern);

    struct ChildBin {
        pattern: Pattern,
        embeddings: HashSet<Vec<VertexId>>,
    }
    let mut bins: HashMap<CanonCode, ChildBin> = HashMap::new();

    // Insert (child pattern, mapping) normalized to the child's canonical
    // vertex numbering, so mappings of isomorphic children generated with
    // different numberings share one position space (correct MNI).
    // canonical_form is O(|Aut-class perms|) and the same raw child
    // pattern recurs once per parent embedding, so memoize it per
    // expansion (§Perf: 4x on FSM at low sigma).
    let mut canon_cache: HashMap<Pattern, (CanonCode, Vec<usize>)> = HashMap::new();
    let mut insert = |bins: &mut HashMap<CanonCode, ChildBin>,
                      child: Pattern,
                      mapping: &[VertexId]| {
        let (code, perm) = canon_cache
            .entry(child.clone())
            .or_insert_with(|| crate::pattern::canonical::canonical_form(&child))
            .clone();
        let mut canon_map = vec![0 as VertexId; mapping.len()];
        for (old, &v) in mapping.iter().enumerate() {
            canon_map[perm[old]] = v;
        }
        let bin = bins.entry(code).or_insert_with(|| ChildBin {
            pattern: child.permuted(&perm),
            embeddings: HashSet::new(),
        });
        bin.embeddings.insert(canon_map);
    };

    for m in embeddings {
        stats.enumerated += 1;
        for i in 0..p_verts {
            let vi = m[i];
            for &x in g.neighbors(vi) {
                if let Some(j) = m.iter().position(|&mv| mv == x) {
                    // back edge (i, j): handle each unordered pair once
                    if j > i || pattern.has_edge(i, j) {
                        continue;
                    }
                    let mut child = pattern.clone();
                    child.add_edge(j, i);
                    insert(&mut bins, child, m);
                } else {
                    // forward edge: new pattern vertex p_verts, label of x
                    let child = grow_pattern(pattern, i, g.label(x));
                    let mut cm = m.clone();
                    cm.push(x);
                    insert(&mut bins, child, &cm);
                }
            }
        }
    }

    let mut children: Vec<(CanonCode, ChildBin)> = bins.into_iter().collect();
    children.sort_by(|a, b| a.0.cmp(&b.0));
    let mut out = Vec::new();
    for (code, bin) in children {
        // duplicate pattern enumeration check: expand this child only
        // from its designated canonical parent
        if canonical_parent_code(&bin.pattern) != parent_code {
            continue;
        }
        let k = bin.pattern.num_vertices();
        let mut d = DomainSupport::new(k);
        for m in &bin.embeddings {
            d.add(m);
        }
        let support = d.support();
        if support <= min_support {
            stats.pruned += 1;
            continue; // anti-monotone: no descendant can be frequent
        }
        out.push(ChildNode {
            code,
            pattern: bin.pattern,
            embeddings: bin.embeddings.into_iter().collect(),
            support,
        });
    }
    out
}

/// BFS (level-synchronous) FSM: the strategy of Pangolin, and effectively
/// of Peregrine's FSM (which "does global synchronization among threads
/// for each DFS iteration ... essentially BFS-like", §6.2). All
/// sub-patterns of one edge count are expanded before any of the next —
/// maximal parallelism, full materialization of every level.
pub fn mine_fsm_bfs(
    g: &CsrGraph,
    max_edges: usize,
    min_support: u64,
    threads: usize,
) -> FsmResult {
    let mut dfs_seed = mine_fsm(g, 1, min_support, threads); // roots only
    let mut level: Vec<(Pattern, Vec<Vec<VertexId>>)> = Vec::new();
    // regenerate root embeddings (mine_fsm doesn't return them)
    {
        let mut roots: HashMap<CanonCode, (Pattern, Vec<Vec<VertexId>>)> = HashMap::new();
        for (u, v) in g.edges() {
            let (a, b) = if g.label(u) <= g.label(v) { (u, v) } else { (v, u) };
            let mut p = Pattern::from_edges(&[(0, 1)]);
            p.set_label(0, g.label(a));
            p.set_label(1, g.label(b));
            let code = canonical_code(&p);
            let e = roots.entry(code).or_insert_with(|| (p, Vec::new()));
            e.1.push(vec![a, b]);
            if g.label(a) == g.label(b) {
                e.1.push(vec![b, a]);
            }
        }
        for (_, (p, embs)) in roots {
            let mut d = DomainSupport::new(2);
            for m in &embs {
                d.add(m);
            }
            if d.support() > min_support {
                level.push((p, embs));
            }
        }
        level.sort_by(|a, b| canonical_code(&a.0).cmp(&canonical_code(&b.0)));
    }
    let mut result = FsmResult {
        frequent: std::mem::take(&mut dfs_seed.frequent),
        stats: dfs_seed.stats,
    };
    for _edge_count in 1..max_edges {
        let expanded = parallel_reduce(
            level.len(),
            threads,
            1,
            || (Vec::new(), SearchStats::default()),
            |(out, stats): &mut (Vec<ChildNode>, SearchStats), i| {
                let (p, embs) = &level[i];
                out.extend(expand_children(g, p, embs, min_support, stats));
            },
            |mut a, b| {
                a.0.extend(b.0);
                a.1.merge(&b.1);
                a
            },
        );
        result.stats.merge(&expanded.1);
        let mut next = Vec::new();
        for child in expanded.0 {
            result.frequent.push(FrequentPattern {
                pattern: child.pattern.clone(),
                code: child.code,
                support: child.support,
                embeddings: child.embeddings.len() as u64,
            });
            next.push((child.pattern, child.embeddings));
        }
        if next.is_empty() {
            break;
        }
        level = next;
    }
    result.frequent.sort_by(|a, b| a.code.cmp(&b.code));
    result
}

fn grow_pattern(p: &Pattern, attach: usize, label: u32) -> Pattern {
    let n = p.num_vertices();
    let mut q = Pattern::new(n + 1);
    for v in 0..n {
        q.set_label(v, p.label(v));
    }
    for (u, v) in p.edges() {
        q.add_edge(u, v);
    }
    q.set_label(n, label);
    q.add_edge(attach, n);
    q
}

/// The designated parent of a pattern: among all single-edge removals
/// that leave a connected pattern (dropping a vertex isolated by the
/// removal), the one with the lexicographically greatest canonical code.
/// Every pattern thus has exactly one generating parent in the
/// sub-pattern tree.
pub fn canonical_parent_code(p: &Pattern) -> CanonCode {
    let n = p.num_vertices();
    let mut best: Option<CanonCode> = None;
    for (u, v) in p.edges() {
        let mut q = Pattern::new(n);
        for w in 0..n {
            q.set_label(w, p.label(w));
        }
        for (a, b) in p.edges() {
            if (a, b) != (u, v) {
                q.add_edge(a, b);
            }
        }
        // drop an isolated endpoint (forward-edge parent)
        let cand = if q.degree(u) == 0 && n > 1 {
            q.induced(((1u32 << n) - 1) as u16 & !(1 << u))
        } else if q.degree(v) == 0 && n > 1 {
            q.induced(((1u32 << n) - 1) as u16 & !(1 << v))
        } else {
            q
        };
        if !cand.is_connected() || cand.num_edges() == 0 {
            continue;
        }
        let code = canonical_code(&cand);
        if best.as_ref().map(|b| code > *b).unwrap_or(true) {
            best = Some(code);
        }
    }
    best.expect("pattern with >=2 edges has a connected parent")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;

    fn labeled_triangle_chain() -> CsrGraph {
        // two triangles sharing a vertex, labels: 1,2,3 around each
        GraphBuilder::from_edges(5, &[(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 2)])
            .with_labels(vec![1, 2, 3, 1, 2])
            .build()
    }

    #[test]
    fn single_edge_patterns_found() {
        let g = labeled_triangle_chain();
        let r = mine_fsm(&g, 1, 0, 1);
        // distinct labeled edges: (1,2),(2,3),(1,3),(3,1)... labels:
        // edges (0,1)=1-2,(1,2)=2-3,(2,0)=3-1,(2,3)=3-1,(3,4)=1-2,(4,2)=2-3
        // distinct: {1,2},{2,3},{1,3} -> 3 patterns
        assert_eq!(r.frequent.len(), 3);
        assert!(r.frequent.iter().all(|f| f.support >= 1));
    }

    #[test]
    fn min_support_filters() {
        let g = labeled_triangle_chain();
        let all = mine_fsm(&g, 2, 0, 1);
        let some = mine_fsm(&g, 2, 1, 1);
        assert!(some.frequent.len() < all.frequent.len());
        assert!(some.frequent.iter().all(|f| f.support > 1));
    }

    #[test]
    fn patterns_unique_by_code() {
        let g = gen::erdos_renyi(40, 0.15, 11, &[1, 2]);
        let r = mine_fsm(&g, 3, 1, 2);
        let mut codes: Vec<_> = r.frequent.iter().map(|f| f.code.clone()).collect();
        let before = codes.len();
        codes.sort();
        codes.dedup();
        assert_eq!(codes.len(), before, "duplicate patterns emitted");
    }

    #[test]
    fn thread_count_invariant() {
        let g = gen::erdos_renyi(40, 0.12, 19, &[1, 2, 3]);
        let a = mine_fsm(&g, 3, 1, 1);
        let b = mine_fsm(&g, 3, 1, 4);
        let sa: Vec<_> = a.frequent.iter().map(|f| (f.code.clone(), f.support)).collect();
        let sb: Vec<_> = b.frequent.iter().map(|f| (f.code.clone(), f.support)).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn canonical_parent_is_deterministic_and_valid() {
        let mut tri = Pattern::from_edges(&[(0, 1), (1, 2), (2, 0)]);
        tri.set_label(0, 1);
        tri.set_label(1, 2);
        tri.set_label(2, 3);
        let parent = canonical_parent_code(&tri);
        // parent of a labeled triangle is one of its 2-edge paths
        let mut path = Pattern::from_edges(&[(0, 1), (1, 2)]);
        // one of the 3 label rotations must match
        let rotations = [(1, 2, 3), (2, 3, 1), (3, 1, 2), (3, 2, 1), (2, 1, 3), (1, 3, 2)];
        let found = rotations.iter().any(|&(a, b, c)| {
            path.set_label(0, a);
            path.set_label(1, b);
            path.set_label(2, c);
            canonical_code(&path) == parent
        });
        assert!(found);
    }

    #[test]
    fn wedge_supports_on_star() {
        // star center label 9, leaves label 1: wedge 1-9-1 has MNI = min(
        // |{leaves}|, |{center}|) = 1; support counts distinct vertices.
        let mut b = GraphBuilder::new(5);
        for v in 1..5 {
            b.add_edge(0, v);
        }
        let g = b.with_labels(vec![9, 1, 1, 1, 1]).build();
        let r = mine_fsm(&g, 2, 0, 1);
        let wedge = r
            .frequent
            .iter()
            .find(|f| f.pattern.num_vertices() == 3)
            .expect("wedge pattern found");
        assert_eq!(wedge.support, 1); // center domain = {0}
    }
}
