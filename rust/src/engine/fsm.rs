//! Frequent Subgraph Mining engine (paper §4.1 "pattern filtering" +
//! §6.2 k-FSM).
//!
//! Implements the paper's strategy: DFS on the *sub-pattern tree* (not
//! the subgraph tree), gSpan-style. Each sub-pattern owns its bin of
//! embeddings (vertex mappings); extension grows every embedding by one
//! edge (edge-induced), children are binned by canonical labeled pattern
//! code, each child pattern is expanded from exactly one canonical
//! parent (duplicate pattern enumeration check), and MNI domain support
//! prunes infrequent sub-patterns before their embeddings are ever
//! generated — the anti-monotone filtering that BFS systems do level by
//! level, done here per-thread without synchronization.
//!
//! # Storage and extension paths (PR 5)
//!
//! Embedding bins are flat SoA arenas
//! ([`EmbArena`]: one `Vec<VertexId>` + stride per bin), so extension
//! is a linear scan over contiguous rows instead of pointer chasing
//! through `HashMap<CanonCode, Vec<Vec<VertexId>>>`, and per-bin
//! deduplication is one deterministic sort
//! ([`EmbArena::sort_dedup`]) instead of a `HashSet` per bin. Within
//! the scan, neighbor classification runs on one of two paths:
//!
//! * **Extension core** (`opts.extcore`, the default): one adaptive
//!   intersection + one anti-intersection against the sorted embedding
//!   ([`ExtCore::members_and_fresh`]) splits each mapped vertex's
//!   neighbors into back-edge and forward-edge targets; back-edge
//!   positions come from a binary search of the (vertex, position)
//!   pairs.
//! * **Scalar oracle** (`opts.extcore` off or `SANDSLASH_NO_EXTCORE=1`):
//!   the seed loop, kept verbatim — a per-neighbor O(k) `position()`
//!   scan of the whole embedding. Results must be bit-identical
//!   (`rust/tests/extcore_differential.rs`).
//!
//! # Scheduling (PR 5)
//!
//! Root-pattern bins fan out through the same
//! [`Splittable`]/[`SplitDriver`] machinery as the DFS and ESU engines:
//! a root's level-1 sequence is its list of frequent canonical children
//! (deterministic — bins sort by code, arenas sort rows), so when a fat
//! root bin would serialize one worker, the untraversed child suffix is
//! published to starving workers as a
//! [`Task::Split`](crate::exec::sched::Task::Split); the split task
//! replays the (worker-local, stats-quiet) child regeneration and
//! recurses only into its window. `MinerConfig::{steal, shards}` and
//! the scoped overrides are honored exactly as in `dfs::mine`.

use std::collections::HashMap;

use crate::exec::sched::{self, Task, WorkerCtx};
use crate::exec::split::{self, SplitDriver, Splittable};
use crate::graph::{CsrGraph, VertexId};
use crate::pattern::{canonical_code, CanonCode, Pattern};
use crate::util::fault;
use crate::util::metrics::{tag, SearchStats};

use super::budget::{self, Governor, MineError, Outcome};
use super::extend::{EmbArena, ExtCore};
use super::opts::MinerConfig;
use super::support::DomainSupport;

#[derive(Clone, Debug)]
/// One frequent pattern with its support evidence.
pub struct FrequentPattern {
    /// The pattern graph.
    pub pattern: Pattern,
    /// Canonical code (dedup key).
    pub code: CanonCode,
    /// Domain (MNI) support.
    pub support: u64,
    /// Number of edge-induced embeddings found.
    pub embeddings: u64,
}

#[derive(Debug, Default)]
/// Output of an FSM run.
pub struct FsmResult {
    /// Frequent patterns, sorted by canonical code.
    pub frequent: Vec<FrequentPattern>,
    /// Search counters.
    pub stats: SearchStats,
}

/// One frequency-filtered root of the sub-pattern tree: a single-edge
/// labeled pattern with its embedding arena.
struct Root {
    pattern: Pattern,
    code: CanonCode,
    embeddings: EmbArena,
}

/// Build the frequency-filtered single-edge roots, binned by canonical
/// labeled code, in deterministic (code) order — shared by the DFS and
/// BFS drivers so the two cannot drift on the seed level.
fn build_roots(g: &CsrGraph, min_support: u64) -> Vec<Root> {
    let mut roots: HashMap<CanonCode, Root> = HashMap::new();
    for (u, v) in g.edges() {
        let (lu, lv) = (g.label(u), g.label(v));
        let mut p = Pattern::from_edges(&[(0, 1)]);
        // canonical orientation: position 0 takes the smaller label
        let (a, b) = if lu <= lv { (u, v) } else { (v, u) };
        p.set_label(0, g.label(a));
        p.set_label(1, g.label(b));
        let code = canonical_code(&p);
        let entry = roots.entry(code.clone()).or_insert_with(|| Root {
            pattern: p,
            code,
            embeddings: EmbArena::new(2),
        });
        entry.embeddings.push_row(&[a, b]);
        // symmetric mapping also valid when labels equal (needed for
        // correct MNI domains)
        if g.label(a) == g.label(b) {
            entry.embeddings.push_row(&[b, a]);
        }
    }
    let mut root_list: Vec<Root> = roots.into_values().collect();
    // deterministic order for reproducibility
    root_list.sort_by(|a, b| a.code.cmp(&b.code));
    for r in &mut root_list {
        r.embeddings.sort_dedup();
    }
    // frequency-filter roots
    root_list.retain(|r| {
        let mut d = DomainSupport::new(2);
        for m in r.embeddings.rows() {
            d.add(m);
        }
        d.support() > min_support
    });
    root_list
}

/// Mine all frequent edge-induced patterns with at most `max_edges`
/// edges and MNI support > `min_support`. Thread count, scheduler
/// knobs, and the extension-core toggle come from `cfg` (the root
/// grain is pinned to 1: root-pattern tasks are coarse).
/// Governed (PR 6): budget trips return a partial [`Outcome`] (the
/// frequent patterns found so far), worker panics return
/// [`MineError::WorkerPanicked`].
pub fn mine_fsm(
    g: &CsrGraph,
    max_edges: usize,
    min_support: u64,
    cfg: &MinerConfig,
) -> Result<Outcome<Vec<FrequentPattern>>, MineError> {
    assert!(g.is_labeled(), "FSM requires a vertex-labeled graph");
    let root_list = build_roots(g, min_support);
    let engine = FsmEngine {
        g,
        roots: &root_list,
        max_edges,
        min_support,
        use_core: cfg.opts.extcore_active(),
    };
    let mut pol_cfg = *cfg;
    pol_cfg.chunk = 1;
    let pol = pol_cfg.sched_policy();
    let gov = budget::governance_enabled().then(|| Governor::new(&cfg.budget));
    let state = split::reduce(
        root_list.len(),
        &pol,
        &engine,
        gov.as_ref(),
        FsmState::default,
        |mut a, b| {
            a.out.frequent.extend(b.out.frequent);
            a.out.stats.merge(&b.out.stats);
            a
        },
    );
    let mut out = state.out;
    // deterministic output order
    out.frequent.sort_by(|a, b| a.code.cmp(&b.code));
    match gov {
        Some(g) => g.finish(out.frequent, out.stats, "fsm"),
        None => Ok(Outcome::complete(out.frequent, out.stats)),
    }
}

/// Per-worker FSM state: the result accumulator plus the reusable
/// extension-core buffers.
#[derive(Default)]
struct FsmState {
    out: FsmResult,
    core: ExtCore,
}

/// The FSM engine as a [`Splittable`] root task (module docs).
struct FsmEngine<'e> {
    g: &'e CsrGraph,
    roots: &'e [Root],
    max_edges: usize,
    min_support: u64,
    use_core: bool,
}

impl Splittable for FsmEngine<'_> {
    type Acc = FsmState;

    fn mine_root(
        &self,
        st: &mut FsmState,
        ctx: &WorkerCtx<'_>,
        root: usize,
        window: Option<(usize, usize)>,
    ) {
        tag::with_engine(tag::Engine::Fsm, || self.root_task(st, ctx, root, window));
    }
}

impl FsmEngine<'_> {
    fn root_task(
        &self,
        st: &mut FsmState,
        ctx: &WorkerCtx<'_>,
        idx: usize,
        window: Option<(usize, usize)>,
    ) {
        debug_assert!(
            window.is_none() || self.use_core,
            "only the extension core publishes FSM splits"
        );
        let r = &self.roots[idx];
        let FsmState { out, core } = st;
        if window.is_none() {
            let mut d = DomainSupport::new(2);
            for m in r.embeddings.rows() {
                d.add(m);
            }
            out.frequent.push(FrequentPattern {
                pattern: r.pattern.clone(),
                code: r.code.clone(),
                support: d.support(),
                embeddings: r.embeddings.len() as u64,
            });
        }
        if self.max_edges <= 1 {
            return;
        }
        // The root's level-1 sequence: its frequent canonical children,
        // a pure function of (graph, root bin, sigma). A split task
        // replays the construction with throwaway stats — the publisher
        // already accounted it (exec::split docs).
        let children = if window.is_none() {
            expand_children(
                self.g, &r.pattern, &r.embeddings, self.min_support, &mut out.stats, core,
                self.use_core,
            )
        } else {
            let mut scratch = SearchStats::default();
            expand_children(
                self.g, &r.pattern, &r.embeddings, self.min_support, &mut scratch, core,
                self.use_core,
            )
        };
        // Publish only when the children recurse (every child of one
        // root has the same edge count, parent + 1): a thief must
        // replay this root's expand_children — the dominant level-1
        // cost here, unlike DFS/ESU's O(deg) setup — so handing away
        // max-depth children (whose remaining work is one Vec push
        // each) would cost strictly more than it parallelizes.
        let deep = children
            .first()
            .is_some_and(|c| c.pattern.num_edges() < self.max_edges);
        debug_assert!(
            window.is_none() || deep,
            "splits are only published for roots with recursing children"
        );
        if self.use_core && deep {
            for pos in SplitDriver::new(ctx, idx, children.len(), window) {
                self.emit_and_recurse(out, core, &children[pos]);
            }
        } else {
            // the scalar oracle (and the no-subtree case) runs whole
            // roots and never publishes; poll per child like the driver
            for child in &children {
                if ctx.cancelled() {
                    break;
                }
                self.emit_and_recurse(out, core, child);
            }
        }
    }

    fn emit_and_recurse(&self, out: &mut FsmResult, core: &mut ExtCore, child: &ChildNode) {
        out.frequent.push(FrequentPattern {
            pattern: child.pattern.clone(),
            code: child.code.clone(),
            support: child.support,
            embeddings: child.embeddings.len() as u64,
        });
        if child.pattern.num_edges() < self.max_edges {
            extend_pattern(
                self.g,
                &child.pattern,
                &child.embeddings,
                self.max_edges,
                self.min_support,
                out,
                core,
                self.use_core,
            );
        }
    }
}

/// One child of a sub-pattern-tree node, ready for support evaluation.
pub struct ChildNode {
    /// Canonical code (dedup key).
    pub code: CanonCode,
    /// The pattern graph.
    pub pattern: Pattern,
    /// Embeddings carried down the sub-pattern tree (sorted, deduped).
    pub embeddings: EmbArena,
    /// Domain (MNI) support.
    pub support: u64,
}

/// Expand one sub-pattern node: generate all one-edge child extensions of
/// all embeddings, bin by child pattern code, keep frequent canonical
/// children, recurse.
#[allow(clippy::too_many_arguments)]
fn extend_pattern(
    g: &CsrGraph,
    pattern: &Pattern,
    embeddings: &EmbArena,
    max_edges: usize,
    min_support: u64,
    acc: &mut FsmResult,
    core: &mut ExtCore,
    use_core: bool,
) {
    for child in expand_children(g, pattern, embeddings, min_support, &mut acc.stats, core, use_core)
    {
        acc.frequent.push(FrequentPattern {
            pattern: child.pattern.clone(),
            code: child.code.clone(),
            support: child.support,
            embeddings: child.embeddings.len() as u64,
        });
        if child.pattern.num_edges() < max_edges {
            extend_pattern(
                g,
                &child.pattern,
                &child.embeddings,
                max_edges,
                min_support,
                acc,
                core,
                use_core,
            );
        }
    }
}

/// One level of sub-pattern-tree expansion: all frequent canonical
/// children of (`pattern`, `embeddings`), in deterministic (code)
/// order with deterministic (sorted) embedding arenas. Shared by the
/// DFS engine above and the BFS engine (`mine_fsm_bfs`) used for
/// system emulation. `use_core` selects the extension-core neighbor
/// classification; the scalar per-neighbor scan is the oracle.
pub fn expand_children(
    g: &CsrGraph,
    pattern: &Pattern,
    embeddings: &EmbArena,
    min_support: u64,
    stats: &mut SearchStats,
    core: &mut ExtCore,
    use_core: bool,
) -> Vec<ChildNode> {
    // the FSM fault-injection point (PR 6): one crossing per
    // sub-pattern expansion, covering root regeneration on split
    // re-entry as well as ordinary tree descent
    fault::point(fault::Stage::FsmRegen);
    let p_verts = pattern.num_vertices();
    let parent_code = canonical_code(pattern);

    struct ChildBin {
        pattern: Pattern,
        embeddings: EmbArena,
    }
    let mut bins: HashMap<CanonCode, ChildBin> = HashMap::new();

    // Insert (child pattern, mapping) normalized to the child's canonical
    // vertex numbering, so mappings of isomorphic children generated with
    // different numberings share one position space (correct MNI).
    // canonical_form is O(|Aut-class perms|) and the same raw child
    // pattern recurs once per parent embedding, so memoize it per
    // expansion (§Perf: 4x on FSM at low sigma).
    let mut canon_cache: HashMap<Pattern, (CanonCode, Vec<usize>)> = HashMap::new();
    let mut canon_map: Vec<VertexId> = Vec::new();
    let mut insert = |bins: &mut HashMap<CanonCode, ChildBin>,
                      canon_map: &mut Vec<VertexId>,
                      child: Pattern,
                      mapping: &[VertexId]| {
        let (code, perm) = canon_cache
            .entry(child.clone())
            .or_insert_with(|| crate::pattern::canonical::canonical_form(&child))
            .clone();
        canon_map.clear();
        canon_map.resize(mapping.len(), 0);
        for (old, &v) in mapping.iter().enumerate() {
            canon_map[perm[old]] = v;
        }
        let bin = bins.entry(code).or_insert_with(|| ChildBin {
            pattern: child.permuted(&perm),
            embeddings: EmbArena::new(mapping.len()),
        });
        bin.embeddings.push_row(canon_map);
    };

    // Reusable per-expansion buffers for the extension-core path.
    let mut pairs: Vec<(VertexId, u32)> = Vec::new();
    let mut sorted_emb: Vec<VertexId> = Vec::new();
    let mut members: Vec<VertexId> = Vec::new();
    let mut fresh: Vec<VertexId> = Vec::new();
    let mut cm: Vec<VertexId> = Vec::new();

    for m in embeddings.rows() {
        stats.enumerated += 1;
        if use_core {
            // Sorted (vertex, position) view of the mapping: one
            // intersection + one anti-intersection per position then
            // classify every neighbor, positions by binary search.
            pairs.clear();
            pairs.extend(m.iter().enumerate().map(|(i, &v)| (v, i as u32)));
            pairs.sort_unstable();
            sorted_emb.clear();
            sorted_emb.extend(pairs.iter().map(|&(v, _)| v));
            for i in 0..p_verts {
                let vi = m[i];
                core.members_and_fresh(g, &sorted_emb, vi, &mut members, &mut fresh);
                for &x in &members {
                    let j = pairs[pairs.binary_search_by_key(&x, |&(v, _)| v).unwrap()].1
                        as usize;
                    // back edge (i, j): handle each unordered pair once
                    if j > i || pattern.has_edge(i, j) {
                        continue;
                    }
                    let mut child = pattern.clone();
                    child.add_edge(j, i);
                    insert(&mut bins, &mut canon_map, child, m);
                }
                for &x in &fresh {
                    // forward edge: new pattern vertex p_verts, label of x
                    let child = grow_pattern(pattern, i, g.label(x));
                    cm.clear();
                    cm.extend_from_slice(m);
                    cm.push(x);
                    insert(&mut bins, &mut canon_map, child, &cm);
                }
            }
        } else {
            // the seed scalar loop, kept verbatim: per-neighbor O(k)
            // position scan of the whole embedding
            for i in 0..p_verts {
                let vi = m[i];
                for &x in g.neighbors(vi) {
                    if let Some(j) = m.iter().position(|&mv| mv == x) {
                        // back edge (i, j): handle each unordered pair once
                        if j > i || pattern.has_edge(i, j) {
                            continue;
                        }
                        let mut child = pattern.clone();
                        child.add_edge(j, i);
                        insert(&mut bins, &mut canon_map, child, m);
                    } else {
                        // forward edge: new pattern vertex p_verts, label of x
                        let child = grow_pattern(pattern, i, g.label(x));
                        cm.clear();
                        cm.extend_from_slice(m);
                        cm.push(x);
                        insert(&mut bins, &mut canon_map, child, &cm);
                    }
                }
            }
        }
    }

    let mut children: Vec<(CanonCode, ChildBin)> = bins.into_iter().collect();
    children.sort_by(|a, b| a.0.cmp(&b.0));
    let mut out = Vec::new();
    for (code, mut bin) in children {
        // duplicate pattern enumeration check: expand this child only
        // from its designated canonical parent
        if canonical_parent_code(&bin.pattern) != parent_code {
            continue;
        }
        // seal the arena: canonical row order, duplicates dropped (the
        // arena replacement for the seed's per-bin HashSet)
        bin.embeddings.sort_dedup();
        let k = bin.pattern.num_vertices();
        let mut d = DomainSupport::new(k);
        for m in bin.embeddings.rows() {
            d.add(m);
        }
        let support = d.support();
        if support <= min_support {
            stats.pruned += 1;
            continue; // anti-monotone: no descendant can be frequent
        }
        out.push(ChildNode { code, pattern: bin.pattern, embeddings: bin.embeddings, support });
    }
    out
}

/// BFS (level-synchronous) FSM: the strategy of Pangolin, and effectively
/// of Peregrine's FSM (which "does global synchronization among threads
/// for each DFS iteration ... essentially BFS-like", §6.2). All
/// sub-patterns of one edge count are expanded before any of the next —
/// maximal parallelism, full materialization of every level.
/// Governed (PR 6) like [`mine_fsm`]: the budget is checked once per
/// delivered task and once per expanded parent; a trip finishes the
/// current level's fan-out and returns the patterns emitted so far as
/// a partial [`Outcome`].
pub fn mine_fsm_bfs(
    g: &CsrGraph,
    max_edges: usize,
    min_support: u64,
    cfg: &MinerConfig,
) -> Result<Outcome<Vec<FrequentPattern>>, MineError> {
    assert!(g.is_labeled(), "FSM requires a vertex-labeled graph");
    let use_core = cfg.opts.extcore_active();
    let mut pol_cfg = *cfg;
    pol_cfg.chunk = 1;
    let pol = pol_cfg.sched_policy();
    let gov = budget::governance_enabled().then(|| Governor::new(&cfg.budget));
    let mut result = FsmResult::default();
    let mut level: Vec<(Pattern, EmbArena)> = Vec::new();
    for r in build_roots(g, min_support) {
        let mut d = DomainSupport::new(2);
        for m in r.embeddings.rows() {
            d.add(m);
        }
        result.frequent.push(FrequentPattern {
            pattern: r.pattern.clone(),
            code: r.code,
            support: d.support(),
            embeddings: r.embeddings.len() as u64,
        });
        level.push((r.pattern, r.embeddings));
    }
    for _edge_count in 1..max_edges {
        if gov.as_ref().is_some_and(|g| g.is_cancelled()) {
            break;
        }
        let expanded = sched::reduce_governed(
            level.len(),
            &pol,
            gov.as_ref(),
            || (Vec::new(), SearchStats::default(), ExtCore::new()),
            |acc: &mut (Vec<ChildNode>, SearchStats, ExtCore), ctx, task| {
                if let Task::Roots { start, end } = task {
                    let (out, stats, core) = acc;
                    for i in start..end {
                        if ctx.cancelled() {
                            break;
                        }
                        let (p, embs) = &level[i];
                        tag::with_engine(tag::Engine::Fsm, || {
                            out.extend(expand_children(
                                g,
                                p,
                                embs,
                                min_support,
                                stats,
                                core,
                                use_core,
                            ));
                        });
                    }
                }
            },
            |mut a, b| {
                a.0.extend(b.0);
                a.1.merge(&b.1);
                a
            },
        );
        result.stats.merge(&expanded.1);
        let mut next = Vec::new();
        for child in expanded.0 {
            result.frequent.push(FrequentPattern {
                pattern: child.pattern.clone(),
                code: child.code,
                support: child.support,
                embeddings: child.embeddings.len() as u64,
            });
            next.push((child.pattern, child.embeddings));
        }
        if next.is_empty() {
            break;
        }
        level = next;
    }
    result.frequent.sort_by(|a, b| a.code.cmp(&b.code));
    match gov {
        Some(g) => g.finish(result.frequent, result.stats, "fsm"),
        None => Ok(Outcome::complete(result.frequent, result.stats)),
    }
}

fn grow_pattern(p: &Pattern, attach: usize, label: u32) -> Pattern {
    let n = p.num_vertices();
    let mut q = Pattern::new(n + 1);
    for v in 0..n {
        q.set_label(v, p.label(v));
    }
    for (u, v) in p.edges() {
        q.add_edge(u, v);
    }
    q.set_label(n, label);
    q.add_edge(attach, n);
    q
}

/// The designated parent of a pattern: among all single-edge removals
/// that leave a connected pattern (dropping a vertex isolated by the
/// removal), the one with the lexicographically greatest canonical code.
/// Every pattern thus has exactly one generating parent in the
/// sub-pattern tree.
pub fn canonical_parent_code(p: &Pattern) -> CanonCode {
    let n = p.num_vertices();
    let mut best: Option<CanonCode> = None;
    for (u, v) in p.edges() {
        let mut q = Pattern::new(n);
        for w in 0..n {
            q.set_label(w, p.label(w));
        }
        for (a, b) in p.edges() {
            if (a, b) != (u, v) {
                q.add_edge(a, b);
            }
        }
        // drop an isolated endpoint (forward-edge parent)
        let cand = if q.degree(u) == 0 && n > 1 {
            q.induced(((1u32 << n) - 1) as u16 & !(1 << u))
        } else if q.degree(v) == 0 && n > 1 {
            q.induced(((1u32 << n) - 1) as u16 & !(1 << v))
        } else {
            q
        };
        if !cand.is_connected() || cand.num_edges() == 0 {
            continue;
        }
        let code = canonical_code(&cand);
        if best.as_ref().map(|b| code > *b).unwrap_or(true) {
            best = Some(code);
        }
    }
    best.expect("pattern with >=2 edges has a connected parent")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::opts::OptFlags;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;

    fn cfg(threads: usize) -> MinerConfig {
        MinerConfig::custom(threads, 1, OptFlags::hi())
    }

    fn labeled_triangle_chain() -> CsrGraph {
        // two triangles sharing a vertex, labels: 1,2,3 around each
        GraphBuilder::from_edges(5, &[(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 2)])
            .with_labels(vec![1, 2, 3, 1, 2])
            .build()
    }

    #[test]
    fn single_edge_patterns_found() {
        let g = labeled_triangle_chain();
        let r = mine_fsm(&g, 1, 0, &cfg(1)).unwrap().value;
        // distinct labeled edges: (1,2),(2,3),(1,3),(3,1)... labels:
        // edges (0,1)=1-2,(1,2)=2-3,(2,0)=3-1,(2,3)=3-1,(3,4)=1-2,(4,2)=2-3
        // distinct: {1,2},{2,3},{1,3} -> 3 patterns
        assert_eq!(r.len(), 3);
        assert!(r.iter().all(|f| f.support >= 1));
    }

    #[test]
    fn min_support_filters() {
        let g = labeled_triangle_chain();
        let all = mine_fsm(&g, 2, 0, &cfg(1)).unwrap().value;
        let some = mine_fsm(&g, 2, 1, &cfg(1)).unwrap().value;
        assert!(some.len() < all.len());
        assert!(some.iter().all(|f| f.support > 1));
    }

    #[test]
    fn patterns_unique_by_code() {
        let g = gen::erdos_renyi(40, 0.15, 11, &[1, 2]);
        let r = mine_fsm(&g, 3, 1, &cfg(2)).unwrap().value;
        let mut codes: Vec<_> = r.iter().map(|f| f.code.clone()).collect();
        let before = codes.len();
        codes.sort();
        codes.dedup();
        assert_eq!(codes.len(), before, "duplicate patterns emitted");
    }

    #[test]
    fn thread_count_invariant() {
        let g = gen::erdos_renyi(40, 0.12, 19, &[1, 2, 3]);
        let a = mine_fsm(&g, 3, 1, &cfg(1)).unwrap().value;
        let b = mine_fsm(&g, 3, 1, &cfg(4)).unwrap().value;
        let sa: Vec<_> = a.iter().map(|f| (f.code.clone(), f.support)).collect();
        let sb: Vec<_> = b.iter().map(|f| (f.code.clone(), f.support)).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn extension_core_matches_scalar_oracle() {
        let g = gen::erdos_renyi(45, 0.12, 7, &[1, 2, 3]);
        for sigma in [0u64, 1, 3] {
            let core = mine_fsm(&g, 3, sigma, &cfg(2)).unwrap().value;
            let mut oracle_cfg = cfg(2);
            oracle_cfg.opts.extcore = false;
            let oracle = mine_fsm(&g, 3, sigma, &oracle_cfg).unwrap().value;
            let sc: Vec<_> = core
                
                .iter()
                .map(|f| (f.code.clone(), f.support, f.embeddings))
                .collect();
            let so: Vec<_> = oracle
                
                .iter()
                .map(|f| (f.code.clone(), f.support, f.embeddings))
                .collect();
            assert_eq!(sc, so, "sigma={sigma}");
        }
    }

    #[test]
    fn canonical_parent_is_deterministic_and_valid() {
        let mut tri = Pattern::from_edges(&[(0, 1), (1, 2), (2, 0)]);
        tri.set_label(0, 1);
        tri.set_label(1, 2);
        tri.set_label(2, 3);
        let parent = canonical_parent_code(&tri);
        // parent of a labeled triangle is one of its 2-edge paths
        let mut path = Pattern::from_edges(&[(0, 1), (1, 2)]);
        // one of the 3 label rotations must match
        let rotations = [(1, 2, 3), (2, 3, 1), (3, 1, 2), (3, 2, 1), (2, 1, 3), (1, 3, 2)];
        let found = rotations.iter().any(|&(a, b, c)| {
            path.set_label(0, a);
            path.set_label(1, b);
            path.set_label(2, c);
            canonical_code(&path) == parent
        });
        assert!(found);
    }

    #[test]
    fn wedge_supports_on_star() {
        // star center label 9, leaves label 1: wedge 1-9-1 has MNI = min(
        // |{leaves}|, |{center}|) = 1; support counts distinct vertices.
        let mut b = GraphBuilder::new(5);
        for v in 1..5 {
            b.add_edge(0, v);
        }
        let g = b.with_labels(vec![9, 1, 1, 1, 1]).build();
        let r = mine_fsm(&g, 2, 0, &cfg(1)).unwrap().value;
        let wedge = r
            
            .iter()
            .find(|f| f.pattern.num_vertices() == 3)
            .expect("wedge pattern found");
        assert_eq!(wedge.support, 1); // center domain = {0}
    }
}
