//! Memoization of Neighborhood Connectivity (paper §4.3, Fig. 5).
//!
//! A thread-private map from data-vertex id to a bit-vector of embedding
//! positions it is adjacent to. Maintained incrementally on DFS
//! push/pop; a single lookup then answers "which embedding vertices is
//! candidate u connected to?" replacing one `has_edge` binary search per
//! (candidate, position) pair.
//!
//! Implemented as open-addressing with linear probing over power-of-two
//! capacity (std `HashMap`'s SipHash is too slow for this hot loop —
//! measured in the §Perf pass). [`Connectivity`] wraps the map together
//! with a dense direct-indexed mode that takes over for high-degree
//! roots (the "bitset mode" of the set-centric extension work).

use crate::graph::VertexId;

const EMPTY: u32 = u32::MAX;

/// Open-addressing map: data-vertex id -> embedding-adjacency bits
/// (the sparse MNC index; see module docs).
pub struct ConnectivityMap {
    keys: Vec<u32>,
    vals: Vec<u32>,
    mask: usize,
    len: usize,
}

impl ConnectivityMap {
    /// Capacity should comfortably exceed the max embedding neighborhood
    /// size (max degree × pattern size); the map grows automatically.
    pub fn with_capacity(cap: usize) -> Self {
        let cap = (cap.max(16) * 2).next_power_of_two();
        Self { keys: vec![EMPTY; cap], vals: vec![0; cap], mask: cap - 1, len: 0 }
    }

    #[inline]
    fn slot(&self, key: u32) -> usize {
        // Fibonacci hashing: good dispersion for near-sequential ids.
        (key.wrapping_mul(0x9E3779B9) as usize) & self.mask
    }

    /// OR `bit` into the entry for `key`.
    #[inline]
    pub fn or_insert(&mut self, key: VertexId, bit: u32) {
        if self.len * 4 >= self.keys.len() * 3 {
            self.grow();
        }
        let mut i = self.slot(key);
        loop {
            let k = self.keys[i];
            if k == key {
                self.vals[i] |= bit;
                return;
            }
            if k == EMPTY {
                self.keys[i] = key;
                self.vals[i] = bit;
                self.len += 1;
                return;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// AND-NOT `bit` out of the entry for `key` (no tombstone removal —
    /// entries with value 0 stay until `clear`; the DFS pops exactly what
    /// it pushed so stale zero entries are rare and harmless).
    #[inline]
    pub fn and_remove(&mut self, key: VertexId, bit: u32) {
        let mut i = self.slot(key);
        loop {
            let k = self.keys[i];
            if k == key {
                self.vals[i] &= !bit;
                return;
            }
            if k == EMPTY {
                return;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Positions bit-vector for `key` (0 when absent).
    #[inline]
    pub fn get(&self, key: VertexId) -> u32 {
        let mut i = self.slot(key);
        loop {
            let k = self.keys[i];
            if k == key {
                return self.vals[i];
            }
            if k == EMPTY {
                return 0;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Reset all entries (O(capacity); the engines prefer symmetric
    /// removal, which is O(touched)).
    pub fn clear(&mut self) {
        self.keys.iter_mut().for_each(|k| *k = EMPTY);
        self.len = 0;
    }

    fn grow(&mut self) {
        let old_keys = std::mem::replace(&mut self.keys, vec![EMPTY; (self.mask + 1) * 2]);
        let old_vals = std::mem::take(&mut self.vals);
        self.vals = vec![0; self.keys.len()];
        self.mask = self.keys.len() - 1;
        self.len = 0;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if k != EMPTY && v != 0 {
                let mut i = self.slot(k);
                loop {
                    if self.keys[i] == EMPTY {
                        self.keys[i] = k;
                        self.vals[i] = v;
                        self.len += 1;
                        break;
                    }
                    i = (i + 1) & self.mask;
                }
            }
        }
    }
}

/// Root degree at which the dense code table beats the hash map: a hub
/// root touches thousands of distinct vertices, so probe chains and
/// hashing lose to a direct-indexed array (measured alongside the
/// kernel crossovers, see EXPERIMENTS.md).
pub const DENSE_ROOT_DEGREE: usize = 512;

/// Adaptive MNC index: hash map for ordinary roots, a direct-indexed
/// dense code table ("bitset mode") for high-degree roots. The dense
/// table is one `u32` position-bitset per data vertex, allocated lazily
/// once per thread; because the DFS pops exactly what it pushes, every
/// root subtree leaves the table zeroed and no clearing pass is needed.
pub struct Connectivity {
    map: ConnectivityMap,
    dense: Vec<u32>,
    use_dense: bool,
}

impl Default for Connectivity {
    fn default() -> Self {
        Self::new()
    }
}

impl Connectivity {
    /// Map-backed index with a default capacity; see `begin_root`.
    pub fn new() -> Self {
        Self {
            map: ConnectivityMap::with_capacity(1024),
            dense: Vec::new(),
            use_dense: false,
        }
    }

    /// Choose the index mode for the next root's subtree. Must be called
    /// before the root's neighborhood is inserted; the mode stays fixed
    /// until the matching symmetric removal completes.
    pub fn begin_root(&mut self, n: usize, root_degree: usize) {
        self.use_dense = root_degree >= DENSE_ROOT_DEGREE;
        if self.use_dense && self.dense.len() < n {
            self.dense.resize(n, 0);
        }
    }

    #[inline]
    /// OR `bit` into the code for `key` (DFS push).
    pub fn or_insert(&mut self, key: VertexId, bit: u32) {
        if self.use_dense {
            self.dense[key as usize] |= bit;
        } else {
            self.map.or_insert(key, bit);
        }
    }

    #[inline]
    /// Clear `bit` from the code for `key` (symmetric DFS pop).
    pub fn and_remove(&mut self, key: VertexId, bit: u32) {
        if self.use_dense {
            self.dense[key as usize] &= !bit;
        } else {
            self.map.and_remove(key, bit);
        }
    }

    #[inline]
    /// Current adjacency code for `key` (0 when absent).
    pub fn get(&self, key: VertexId) -> u32 {
        if self.use_dense {
            self.dense[key as usize]
        } else {
            self.map.get(key)
        }
    }

    #[inline]
    /// Whether the dense direct-indexed table is active for the current
    /// root (decided by [`begin_root`](Self::begin_root)).
    pub fn is_dense(&self) -> bool {
        self.use_dense
    }

    /// Filter `cands` down to those whose adjacency code `c` satisfies
    /// `c & want == want && c & veto == 0`, appending survivors to
    /// `out` in input order — the whole-row connectivity probe. In
    /// dense mode the codes are gathered and tested with the
    /// vectorized kernels in [`crate::graph::setops`]
    /// (EXPERIMENTS.md §PR-3); in map mode each code is probed
    /// individually (hash lookups cannot be gathered).
    pub fn filter_into(
        &self,
        cands: &[VertexId],
        want: u32,
        veto: u32,
        out: &mut Vec<VertexId>,
    ) {
        if self.use_dense {
            crate::graph::setops::gather_mask_filter_into(&self.dense, cands, want, veto, out);
        } else {
            for &u in cands {
                let c = self.map.get(u);
                if c & want == want && c & veto == 0 {
                    out.push(u);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut m = ConnectivityMap::with_capacity(8);
        m.or_insert(100, 1 << 0);
        m.or_insert(100, 1 << 2);
        m.or_insert(7, 1 << 1);
        assert_eq!(m.get(100), 0b101);
        assert_eq!(m.get(7), 0b10);
        assert_eq!(m.get(42), 0);
        m.and_remove(100, 1 << 0);
        assert_eq!(m.get(100), 0b100);
    }

    #[test]
    fn grows_past_initial_capacity() {
        let mut m = ConnectivityMap::with_capacity(4);
        for k in 0..1000u32 {
            m.or_insert(k, 1);
        }
        for k in 0..1000u32 {
            assert_eq!(m.get(k), 1, "key {k}");
        }
    }

    #[test]
    fn collision_chains_probe_correctly() {
        let mut m = ConnectivityMap::with_capacity(16);
        // keys engineered to collide under the multiplier are hard to
        // construct portably; hammer adjacent ids instead
        for k in 0..20u32 {
            m.or_insert(k, 1 << (k % 30));
        }
        for k in 0..20u32 {
            assert_eq!(m.get(k), 1 << (k % 30));
        }
    }

    #[test]
    fn dense_and_hash_modes_agree() {
        let n = 4096;
        let mut hash = Connectivity::new();
        hash.begin_root(n, 4); // below the threshold: hash mode
        let mut dense = Connectivity::new();
        dense.begin_root(n, DENSE_ROOT_DEGREE); // at threshold: dense mode
        for k in (0..n as u32).step_by(7) {
            hash.or_insert(k, 1 << (k % 20));
            dense.or_insert(k, 1 << (k % 20));
        }
        for k in 0..n as u32 {
            assert_eq!(hash.get(k), dense.get(k), "key {k}");
        }
        for k in (0..n as u32).step_by(14) {
            hash.and_remove(k, 1 << (k % 20));
            dense.and_remove(k, 1 << (k % 20));
        }
        for k in 0..n as u32 {
            assert_eq!(hash.get(k), dense.get(k), "key {k} after removal");
        }
    }

    #[test]
    fn filter_into_agrees_across_modes_and_with_get() {
        let n = 2048usize;
        let mut hash = Connectivity::new();
        hash.begin_root(n, 4); // hash mode
        let mut dense = Connectivity::new();
        dense.begin_root(n, DENSE_ROOT_DEGREE); // dense mode
        assert!(!hash.is_dense() && dense.is_dense());
        for k in (0..n as u32).step_by(3) {
            hash.or_insert(k, 1 << (k % 12));
            dense.or_insert(k, 1 << (k % 12));
        }
        let cands: Vec<u32> = (0..n as u32).step_by(2).collect();
        let (want, veto) = (1u32 << 3, 1u32 << 9);
        let mut from_hash = Vec::new();
        hash.filter_into(&cands, want, veto, &mut from_hash);
        let mut from_dense = Vec::new();
        dense.filter_into(&cands, want, veto, &mut from_dense);
        let reference: Vec<u32> = cands
            .iter()
            .copied()
            .filter(|&u| {
                let c = hash.get(u);
                c & want == want && c & veto == 0
            })
            .collect();
        assert_eq!(from_hash, reference);
        assert_eq!(from_dense, reference);
    }

    #[test]
    fn fig5_scenario() {
        // Paper Fig. 5: v3 adjacent to v0 (position 0) and v2 (position 2).
        let mut m = ConnectivityMap::with_capacity(8);
        let v3 = 3u32;
        m.or_insert(v3, 1 << 0); // when v0 pushed
        m.or_insert(v3, 1 << 2); // when v2 pushed
        assert_eq!(m.get(v3), 0b101); // positions {0, 2}
    }
}
