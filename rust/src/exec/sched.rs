//! The work-stealing, locality-sharded scheduler.
//!
//! Replaces the single global chunk cursor of the seed `util::pool`
//! substrate. That cursor gave dynamic load balance, but on skewed
//! inputs a hub-rooted subtree serializes the tail of the run, and on
//! multi-socket hosts every claim bounces one contended cache line
//! across sockets. This module keeps the same execution model — `n`
//! independent root tasks, per-worker accumulators, one merge at the
//! end — and restructures *who claims what from where*:
//!
//! * **Per-worker bounded deques** (`DEQUE_CAP`). A worker that
//!   acquires a block of roots lazily halves it into the deque
//!   (`run_task`): it keeps the low half (ascending order ⇒ the CSR
//!   prefetch pattern of the old cursor) and leaves the high half
//!   stealable. Local pops are LIFO (back), steals are FIFO (front),
//!   so the owner works on the cache-warm small ranges while thieves
//!   take the biggest, oldest ranges — the classic deque discipline.
//! * **Shard-local cursors** ([`crate::exec::topology`]). The root
//!   space `0..n` is partitioned into one contiguous range per
//!   locality shard, each with its own claim cursor; workers are
//!   pinned to shards round-robin. A worker claims and steals inside
//!   its shard until the *whole shard* drains, and only then crosses
//!   shards (randomized order) — claim traffic stays on-socket for the
//!   bulk of a run.
//! * **Adaptive subtree splitting** ([`crate::exec::split`]). When
//!   stealing finds nothing, starving workers raise a demand flag that
//!   loaded workers answer by publishing the untraversed suffix of
//!   their current root's level-1 candidate set ([`Task::Split`]) —
//!   bounding the longest sequential chain on hub roots.
//!
//! The seed scheduler is **kept** as `cursor_reduce`, selected by
//! `SchedPolicy { steal: false, .. }`, the `SANDSLASH_NO_STEAL=1`
//! environment kill switch, or
//! [`MinerConfig::with_steal`](crate::engine::MinerConfig::with_steal)`(false)`:
//! it is the *scheduling oracle* — every count must be invariant under
//! the scheduler swap (`rust/tests/sched_invariance.rs`), exactly as
//! the scalar kernels referee the SIMD dispatch.
//!
//! Every scheduling event (block claim, steal, cross-shard claim,
//! split publish) bumps a counter in [`crate::util::metrics::sched`],
//! so tests and benches assert that stealing actually fires instead of
//! trusting that it might.
//!
//! **Query governance (PR 6).** [`reduce_governed`] threads an optional
//! [`Governor`] through every execution path (sequential, cursor
//! oracle, stealing pool): each delivered task is charged against the
//! run's deadline/task budget before the body runs, and worker bodies
//! execute under `catch_unwind`, so a panicking hook records its
//! payload (first panic wins), flips the shared cancel token, drains
//! the panicking worker's own deque, and lets the run terminate through
//! the normal `active == 0` protocol instead of poisoning the deque
//! mutexes and hanging the idle sweep. Ungoverned pool runs keep the
//! propagate-to-caller contract by re-raising the captured payload with
//! `resume_unwind` after the scope joins; with no governor present the
//! hot path is bit-identical to PR 5 ([`reduce`] forwards `gov: None`).

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::OnceLock;

use crate::engine::budget::Governor;
use crate::obs::{flight, trace as qtrace};
use crate::util::metrics::sched as counters;
use crate::util::rng::Rng;
// PR-8: the protocol state (deque mutexes + length mirrors, the
// active-count termination protocol, the stop flag) and the worker
// threads themselves go through the sync facade so the loom suite can
// model-check them (tests/loom/sched.rs proves no task is lost at
// termination). OnceLock stays std: process-lifetime env caching is
// not part of the protocol under test.
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::{thread as sthread, Mutex};

use super::split::SplitGate;
use super::topology;

/// Cursor claims hand out `chunk * BLOCK_FACTOR` roots at a time: the
/// deque (not the shared cursor) is the fine-grained balancing layer,
/// so blocks can be coarse — one claim per 8 old-style chunks cuts
/// cursor traffic 8× while lazy halving restores the old granularity
/// locally (EXPERIMENTS.md §PR-4).
const BLOCK_FACTOR: usize = 8;

/// Bound on each worker deque. Lazy halving pushes O(log block) ranges
/// and splits push one task at a time, so the bound exists only to keep
/// a pathological caller from growing the deque without limit; at the
/// cap, ranges are simply processed inline instead of published.
const DEQUE_CAP: usize = 1024;

/// Failed sweeps before an idle worker starts sleeping between sweeps
/// instead of spinning — keeps the starving tail from burning cores
/// while one long subtree finishes (splits usually resolve it first).
const IDLE_SPINS: u32 = 64;

/// Nap length for long-idle workers (termination and split latency
/// stay far below any measurable task length).
const IDLE_NAP: std::time::Duration = std::time::Duration::from_micros(50);

/// Process-wide steal default: `false` only under `SANDSLASH_NO_STEAL`
/// (any non-empty value other than `0`), the CI oracle job's kill
/// switch — same contract as `SANDSLASH_NO_SIMD`. Cached for the
/// process lifetime.
pub fn steal_enabled_default() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(|| {
        !std::env::var("SANDSLASH_NO_STEAL")
            .is_ok_and(|v| !v.trim().is_empty() && v.trim() != "0")
    })
}

/// Scoped, thread-local scheduling overrides (see [`with_overrides`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Overrides {
    /// `Some(false)` pins runs to the cursor oracle, `Some(true)` asks
    /// for stealing (the `SANDSLASH_NO_STEAL` kill switch still wins).
    pub steal: Option<bool>,
    /// Explicit shard count for [`SchedPolicy::auto`] resolution.
    pub shards: Option<usize>,
}

thread_local! {
    static OVERRIDES: Cell<Overrides> = const { Cell::new(Overrides { steal: None, shards: None }) };
}

/// Run `f` with scheduling overrides active on *this thread*: every
/// policy resolved inside (the `util::pool` adapters and
/// [`MinerConfig::sched_policy`](crate::engine::MinerConfig::sched_policy))
/// sees them. Thread-local and scoped (restored on return, nesting
/// safe), so concurrent tests can sweep steal/shard settings without
/// racing on process globals. The workers a run spawns inherit the
/// policy resolved *at launch*, not the thread-local itself.
///
/// **Reentrancy (PR 7)**: this scoping is what lets the resident
/// service multiplex queries. Each [`reduce`] call builds its own pool
/// over its own root set, so any number of root sets can be in flight
/// at once — overrides installed on one query's thread are invisible
/// to every other query's, and the restore-on-exit guard means a pool
/// thread that later serves a different query starts from that
/// query's own ambient state, never a leaked one (same contract as
/// [`budget::with_cancel`](crate::engine::budget::with_cancel);
/// asserted by `tests/service_concurrency.rs` and the
/// `simultaneous_root_sets_are_isolated` test below).
pub fn with_overrides<T>(ov: Overrides, f: impl FnOnce() -> T) -> T {
    let prev = OVERRIDES.with(|c| c.replace(ov));
    struct Restore(Overrides);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDES.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(prev);
    f()
}

/// The overrides currently active on this thread.
pub(crate) fn current_overrides() -> Overrides {
    OVERRIDES.with(|c| c.get())
}

/// Resolved execution policy for one `reduce`/`for_each` run.
#[derive(Clone, Copy, Debug)]
pub struct SchedPolicy {
    /// Worker thread count.
    pub threads: usize,
    /// Grain: roots processed per deque interaction (the old dynamic
    /// self-scheduling chunk, same default — see
    /// [`crate::util::pool::default_chunk`]).
    pub chunk: usize,
    /// `false` selects the global-cursor oracle (`cursor_reduce`).
    pub steal: bool,
    /// Locality shard count (clamped to `threads` at pool build).
    pub shards: usize,
}

impl SchedPolicy {
    /// The single policy resolver (one implementation so the adapter
    /// and engine paths cannot drift): the `SANDSLASH_NO_STEAL` kill
    /// switch wins over everything, a scoped thread-local override
    /// wins over the caller's per-run defaults, and shards fall back
    /// from override → per-run default → detected topology.
    pub fn resolve(
        threads: usize,
        chunk: usize,
        steal_default: bool,
        shards_default: Option<usize>,
    ) -> Self {
        let ov = current_overrides();
        Self {
            threads,
            chunk,
            steal: steal_enabled_default() && ov.steal.unwrap_or(steal_default),
            shards: ov.shards.or(shards_default).unwrap_or_else(topology::shards),
        }
    }

    /// Default resolution for callers that only know `threads`/`chunk`
    /// (the `util::pool` adapters): stealing on unless the
    /// `SANDSLASH_NO_STEAL` kill switch or a thread-local override
    /// says otherwise, shards from the override or detected topology.
    pub fn auto(threads: usize, chunk: usize) -> Self {
        Self::resolve(threads, chunk, true, None)
    }
}

/// One unit of scheduled work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// A contiguous range `[start, end)` of root indices.
    Roots {
        /// First root index (inclusive).
        start: usize,
        /// One past the last root index.
        end: usize,
    },
    /// A published suffix `[lo, hi)` of one root's level-1 candidate
    /// positions (see [`crate::exec::split`]); only ever created by a
    /// body that calls [`WorkerCtx::publish_split`], and delivered
    /// back to the same body to execute.
    Split {
        /// The root vertex whose level-1 candidates were split.
        root: usize,
        /// First candidate position (inclusive) of the suffix.
        lo: usize,
        /// One past the last candidate position.
        hi: usize,
    },
}

/// Per-worker handle passed to the body: identifies the worker (for
/// worker-indexed scratch) and carries the split-protocol endpoints.
/// In sequential and cursor-oracle runs the handle is inert — splits
/// are never requested and never publish.
pub struct WorkerCtx<'p> {
    /// Stable worker id in `0..threads`.
    pub worker: usize,
    pool: Option<&'p Pool>,
    gov: Option<&'p Governor>,
}

impl WorkerCtx<'_> {
    /// Whether the run's governor has tripped (deadline, task budget,
    /// caller token, or a caught worker panic). One relaxed load —
    /// engine bodies poll this at the sites the split gate already
    /// polls (per level-1 candidate, per claimed block, per BFS level)
    /// and bail out early. Always `false` in ungoverned runs.
    #[inline]
    pub fn cancelled(&self) -> bool {
        self.gov.is_some_and(|g| g.is_cancelled())
    }

    /// Whether a starving worker is waiting for work *and* this
    /// worker's own deque has nothing left to steal — the signal that
    /// publishing a level-1 suffix would actually relieve someone
    /// (one relaxed load each; safe to poll from a hot loop).
    pub fn split_requested(&self) -> bool {
        match self.pool {
            Some(p) => {
                p.gate.requests_pending()
                    && p.queues[self.worker].len.load(Ordering::Relaxed) == 0
            }
            None => false,
        }
    }

    /// Publish candidate positions `[lo, hi)` of `root`'s level-1 set
    /// as a stealable [`Task::Split`]. Returns `false` (publish
    /// nothing) when the demand signal has lapsed, the suffix is
    /// empty, or the deque is at capacity — the caller keeps the
    /// suffix and continues sequentially in that case.
    pub fn publish_split(&self, root: usize, lo: usize, hi: usize) -> bool {
        let Some(p) = self.pool else { return false };
        if lo >= hi || !self.split_requested() {
            return false;
        }
        // front = steal end: starving workers should see the split
        // before the owner's own range backlog.
        if p.push_front(self.worker, Task::Split { root, lo, hi }) {
            counters::note_split();
            qtrace::on_split();
            flight::note_split();
            true
        } else {
            false
        }
    }
}

/// One shard's claim cursor, alone on its cache line so cross-shard
/// traffic never false-shares with a neighbor's claims.
#[repr(align(64))]
struct ShardCursor {
    next: AtomicUsize,
    end: usize,
}

struct WorkerQueue {
    /// Deque length mirror, maintained under the lock, read lock-free
    /// by thieves (skip empty victims) and by the split poll.
    len: AtomicUsize,
    deque: Mutex<VecDeque<Task>>,
}

struct Pool {
    cursors: Vec<ShardCursor>,
    queues: Vec<WorkerQueue>,
    worker_shard: Vec<usize>,
    shard_workers: Vec<Vec<usize>>,
    gate: SplitGate,
    /// Workers currently *sweeping for or executing* a task. Raised
    /// before a sweep begins, so a task is never invisible (out of its
    /// deque/cursor, holder uncounted): any task a peer's sweep misses
    /// is held by a worker still counted here. Termination requires
    /// observing `active == 0` *and* a subsequent thorough sweep
    /// finding nothing — only a counted worker can hold or publish
    /// work, so once both hold, no work exists and none can appear.
    active: AtomicUsize,
    /// First panic payload caught from a worker body in an *ungoverned*
    /// run, re-raised on the caller thread after the scope joins — the
    /// pre-PR-6 propagate contract, minus the poisoned deque mutexes
    /// and the `active`-count hang a mid-task unwind used to cause.
    /// Governed runs stringify the payload into the [`Governor`]
    /// instead.
    panic_payload: Mutex<Option<Box<dyn Any + Send>>>,
    /// Raised when any worker body panics, so every worker (governed or
    /// not) stops claiming at its next loop check instead of draining
    /// the remaining root space for a run whose result is already lost.
    stop: AtomicBool,
    grain: usize,
    block: usize,
}

impl Pool {
    fn new(n: usize, pol: &SchedPolicy) -> Self {
        let threads = pol.threads.max(1);
        let shards = pol.shards.clamp(1, threads);
        let grain = pol.chunk.max(1);
        let cursors = (0..shards)
            .map(|s| {
                let (lo, hi) = topology::shard_range(s, shards, n);
                ShardCursor { next: AtomicUsize::new(lo), end: hi }
            })
            .collect();
        let worker_shard: Vec<usize> =
            (0..threads).map(|w| topology::shard_of(w, shards)).collect();
        let mut shard_workers = vec![Vec::new(); shards];
        for (w, &s) in worker_shard.iter().enumerate() {
            shard_workers[s].push(w);
        }
        Self {
            cursors,
            queues: (0..threads)
                .map(|_| WorkerQueue { len: AtomicUsize::new(0), deque: Mutex::new(VecDeque::new()) })
                .collect(),
            worker_shard,
            shard_workers,
            gate: SplitGate::new(),
            active: AtomicUsize::new(0),
            panic_payload: Mutex::new(None),
            stop: AtomicBool::new(false),
            grain,
            block: grain.saturating_mul(BLOCK_FACTOR),
        }
    }

    /// LIFO pop from the worker's own deque.
    fn pop_local(&self, w: usize) -> Option<Task> {
        let q = &self.queues[w];
        if q.len.load(Ordering::Relaxed) == 0 {
            return None;
        }
        let mut d = q.deque.lock().unwrap();
        let t = d.pop_back();
        q.len.store(d.len(), Ordering::Relaxed);
        t
    }

    /// Bounded push to the back (owner end) of `w`'s deque.
    fn push_back(&self, w: usize, t: Task) -> bool {
        let q = &self.queues[w];
        let mut d = q.deque.lock().unwrap();
        if d.len() >= DEQUE_CAP {
            return false;
        }
        d.push_back(t);
        q.len.store(d.len(), Ordering::Relaxed);
        true
    }

    /// Bounded push to the front (steal end) of `w`'s deque.
    fn push_front(&self, w: usize, t: Task) -> bool {
        let q = &self.queues[w];
        let mut d = q.deque.lock().unwrap();
        if d.len() >= DEQUE_CAP {
            return false;
        }
        d.push_front(t);
        q.len.store(d.len(), Ordering::Relaxed);
        true
    }

    /// Claim one block of roots from a shard cursor.
    fn claim(&self, shard: usize, own: bool) -> Option<Task> {
        let c = &self.cursors[shard];
        // cheap pre-check keeps drained-cursor polling from growing the
        // counter unboundedly; the fetch_add below stays the arbiter
        if c.next.load(Ordering::Relaxed) >= c.end {
            return None;
        }
        let start = c.next.fetch_add(self.block, Ordering::Relaxed);
        if start >= c.end {
            return None;
        }
        if own {
            counters::note_claim();
            qtrace::on_claim();
        } else {
            counters::note_shard_claim();
            qtrace::on_shard_claim();
        }
        Some(Task::Roots { start, end: (start + self.block).min(c.end) })
    }

    /// FIFO steal from one victim's deque. `thorough` skips the
    /// lock-free emptiness shortcut (used by the termination sweep,
    /// which must not trust a stale length mirror).
    fn steal_from(&self, victim: usize, thief: usize, thorough: bool) -> Option<Task> {
        if victim == thief {
            return None;
        }
        let q = &self.queues[victim];
        if !thorough && q.len.load(Ordering::Relaxed) == 0 {
            return None;
        }
        let mut d = q.deque.lock().unwrap();
        let t = d.pop_front();
        q.len.store(d.len(), Ordering::Relaxed);
        if t.is_some() {
            counters::note_steal();
            qtrace::on_steal();
            flight::note_steal(victim);
        }
        t
    }

    /// Randomized-order steal sweep over one shard's workers.
    fn steal_in_shard(&self, shard: usize, thief: usize, rng: &mut Rng, thorough: bool) -> Option<Task> {
        let ws = &self.shard_workers[shard];
        if ws.is_empty() {
            return None;
        }
        let k0 = rng.below(ws.len() as u64) as usize;
        for i in 0..ws.len() {
            if let Some(t) = self.steal_from(ws[(k0 + i) % ws.len()], thief, thorough) {
                return Some(t);
            }
        }
        None
    }

    /// Full acquisition order: own deque (LIFO) → own shard cursor →
    /// steal inside own shard → foreign shards (randomized rotation),
    /// cursor before deques within each. Steals leave a shard only
    /// after that shard has fully drained.
    fn find_work(&self, w: usize, rng: &mut Rng, thorough: bool) -> Option<Task> {
        if let Some(t) = self.pop_local(w) {
            return Some(t);
        }
        let my = self.worker_shard[w];
        if let Some(t) = self.claim(my, true) {
            return Some(t);
        }
        if let Some(t) = self.steal_in_shard(my, w, rng, thorough) {
            return Some(t);
        }
        let ns = self.cursors.len();
        if ns > 1 {
            let s0 = rng.below(ns as u64) as usize;
            for i in 0..ns {
                let s = (s0 + i) % ns;
                if s == my {
                    continue;
                }
                if let Some(t) = self.claim(s, false) {
                    return Some(t);
                }
                if let Some(t) = self.steal_in_shard(s, w, rng, thorough) {
                    return Some(t);
                }
            }
        }
        None
    }

    /// Record a caught worker-body panic: drain the panicking worker's
    /// own deque (its queued sub-ranges belong to an abandoned run),
    /// keep the first payload — stringified into the governor when one
    /// is present, boxed for `resume_unwind` otherwise — and raise the
    /// pool stop flag. The worker then decrements `active` and exits
    /// through the normal termination protocol.
    fn note_worker_panic(&self, w: usize, payload: Box<dyn Any + Send>, gov: Option<&Governor>) {
        {
            let mut d = self.queues[w].deque.lock().unwrap_or_else(|e| e.into_inner());
            d.clear();
            self.queues[w].len.store(0, Ordering::Relaxed);
        }
        match gov {
            // the governor records the flight-recorder panic event and
            // dumps the trail when its token trips
            Some(g) => g.note_panic(panic_message(payload.as_ref())),
            None => {
                flight::note_panic();
                flight::dump_to_stderr("worker-panic");
                let mut slot = self.panic_payload.lock().unwrap_or_else(|e| e.into_inner());
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        }
        self.stop.store(true, Ordering::SeqCst);
    }
}

/// Best-effort human-readable form of a panic payload: the `&str` and
/// `String` payloads `panic!` produces, a marker for anything else.
fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Execute one task: splits go straight to the body; root ranges are
/// lazily halved into the deque down to the grain, keeping the low half
/// (ascending order) and leaving the high halves stealable.
fn run_task<A>(
    pool: &Pool,
    task: Task,
    acc: &mut A,
    ctx: &WorkerCtx<'_>,
    body: &(impl Fn(&mut A, &WorkerCtx<'_>, Task) + Sync),
) {
    match task {
        Task::Split { .. } => body(acc, ctx, task),
        Task::Roots { start, end } => {
            let (s, mut e) = (start, end);
            while e - s > pool.grain {
                let mid = s + (e - s) / 2;
                if pool.push_back(ctx.worker, Task::Roots { start: mid, end: e }) {
                    e = mid;
                } else {
                    break; // deque at capacity: just run the rest inline
                }
            }
            body(acc, ctx, Task::Roots { start: s, end: e });
        }
    }
}

fn worker_loop<A>(
    pool: &Pool,
    w: usize,
    gov: Option<&Governor>,
    init: &(impl Fn() -> A + Sync),
    body: &(impl Fn(&mut A, &WorkerCtx<'_>, Task) + Sync),
) -> A {
    let mut acc = init();
    let ctx = WorkerCtx { worker: w, pool: Some(pool), gov };
    // worker-seeded xoshiro: victim selection must differ per worker or
    // thieves convoy on one victim's lock
    let mut rng = Rng::seeded(0x9E37_79B9_7F4A_7C15 ^ (w as u64).wrapping_mul(0x0A07_61D6_478B_D642));
    let mut hungry = false;
    let mut idle = 0u32;
    // Acquire-and-run under the `active` count: raised BEFORE the sweep
    // so a claimed task is never invisible to peers' termination checks
    // (see the `Pool::active` docs). Returns whether a task ran. The
    // body runs under `catch_unwind`: an unwinding hook must not skip
    // the `active` decrement, or every peer spins forever waiting for
    // `active == 0` (the pre-PR-6 failure mode).
    let mut try_work = |acc: &mut A, hungry: &mut bool, thorough: bool| -> bool {
        pool.active.fetch_add(1, Ordering::SeqCst);
        match pool.find_work(w, &mut rng, thorough) {
            Some(task) => {
                if *hungry {
                    pool.gate.deregister();
                    *hungry = false;
                }
                let run = catch_unwind(AssertUnwindSafe(|| run_task(pool, task, acc, &ctx, body)));
                if let Err(payload) = run {
                    pool.note_worker_panic(w, payload, gov);
                }
                pool.active.fetch_sub(1, Ordering::SeqCst);
                true
            }
            None => {
                pool.active.fetch_sub(1, Ordering::SeqCst);
                false
            }
        }
    };
    loop {
        // a caught panic (any run) or a tripped governor (deadline,
        // budget, caller) stops claiming; tasks still queued are
        // abandoned — the run's result is partial or lost either way
        if pool.stop.load(Ordering::Relaxed) || ctx.cancelled() {
            break;
        }
        if try_work(&mut acc, &mut hungry, false) {
            idle = 0;
            continue;
        }
        if !hungry {
            pool.gate.register();
            hungry = true;
        }
        if pool.active.load(Ordering::SeqCst) == 0 {
            // no counted worker ⇒ nothing is held or publishable from
            // here on; one thorough sweep (locking every deque)
            // separates a missed task from termination
            if try_work(&mut acc, &mut hungry, true) {
                idle = 0;
                continue;
            }
            break;
        }
        idle += 1;
        if idle < IDLE_SPINS {
            sthread::yield_now();
        } else {
            sthread::sleep(IDLE_NAP);
        }
    }
    if hungry {
        pool.gate.deregister();
    }
    acc
}

/// The seed scheduler, kept as the scheduling oracle: one global
/// cursor, fixed `chunk`-sized claims, workers exit when the cursor
/// drains. No deques, no shards, no splits — every count must match it
/// exactly under any stealing configuration. Governed runs honor the
/// same token/budget as the stealing pool (so core-vs-oracle
/// differential tests compare like with like) via a separate loop
/// body; the ungoverned loop is the seed path verbatim.
fn cursor_reduce<A: Send>(
    n: usize,
    threads: usize,
    chunk: usize,
    gov: Option<&Governor>,
    init: &(impl Fn() -> A + Sync),
    body: &(impl Fn(&mut A, &WorkerCtx<'_>, Task) + Sync),
    merge: impl FnMut(A, A) -> A,
) -> A {
    let cursor = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    // Thread-locals do not cross the scope boundary: capture the
    // caller's trace (if one is installed) and re-install it inside
    // every worker, so a traced query's events land in its own
    // profile no matter which thread mines them (PR 9).
    let trace = qtrace::current();
    let results: Vec<A> = sthread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|tid| {
                let cursor = &cursor;
                let stop = &stop;
                let trace = trace.clone();
                scope.spawn(move || {
                    qtrace::with_optional(trace, || {
                        let mut acc = init();
                        let ctx = WorkerCtx { worker: tid, pool: None, gov };
                        match gov {
                            None => loop {
                                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                                if start >= n {
                                    break;
                                }
                                body(
                                    &mut acc,
                                    &ctx,
                                    Task::Roots { start, end: (start + chunk).min(n) },
                                );
                            },
                            Some(g) => loop {
                                if stop.load(Ordering::Relaxed) || g.is_cancelled() {
                                    break;
                                }
                                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                                if start >= n {
                                    break;
                                }
                                let task = Task::Roots { start, end: (start + chunk).min(n) };
                                let run =
                                    catch_unwind(AssertUnwindSafe(|| body(&mut acc, &ctx, task)));
                                if let Err(payload) = run {
                                    g.note_panic(panic_message(payload.as_ref()));
                                    stop.store(true, Ordering::SeqCst);
                                    break;
                                }
                            },
                        }
                        acc
                    })
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    fold(results, merge)
}

fn fold<A>(results: Vec<A>, mut merge: impl FnMut(A, A) -> A) -> A {
    let mut it = results.into_iter();
    let first = it.next().expect("at least one worker");
    it.fold(first, |a, b| merge(a, b))
}

/// Parallel map-reduce over root tasks `0..n` under `pol`: `init`
/// builds one accumulator per worker, `body` executes one [`Task`]
/// into it, `merge` combines the per-worker results once at the end
/// (no synchronization on the mining path). Runs sequentially when
/// `threads == 1` or `n <= chunk` (bit-for-bit the pre-PR-4 contract),
/// on the cursor oracle when `pol.steal` is off, and on the sharded
/// stealing pool otherwise. Ungoverned: forwards to
/// [`reduce_governed`] with no [`Governor`].
pub fn reduce<A: Send>(
    n: usize,
    pol: &SchedPolicy,
    init: impl Fn() -> A + Sync,
    body: impl Fn(&mut A, &WorkerCtx<'_>, Task) + Sync,
    merge: impl FnMut(A, A) -> A,
) -> A {
    reduce_governed(n, pol, None, init, body, merge)
}

/// [`reduce`] under an optional [`Governor`] (PR 6): every delivered
/// task — a grain-sized root range, a published split, a BFS expansion
/// block — is charged with [`Governor::admit`] before the body runs,
/// and worker bodies execute under `catch_unwind` so a panicking hook
/// becomes a recorded cancellation instead of a poisoned pool. With
/// `gov: None` this is exactly [`reduce`]: no charges, no catching
/// (pool runs still catch, then re-raise after the scope joins — the
/// propagate contract with the hang fixed), no per-task branches
/// beyond one `Option` test.
///
/// Accumulators of tasks whose body unwound are still merged: the
/// governed caller discards the merged value via
/// [`Governor::finish`](crate::engine::budget::Governor::finish)
/// returning `Err`, so a half-updated accumulator is never observable.
pub fn reduce_governed<A: Send>(
    n: usize,
    pol: &SchedPolicy,
    gov: Option<&Governor>,
    init: impl Fn() -> A + Sync,
    body: impl Fn(&mut A, &WorkerCtx<'_>, Task) + Sync,
    merge: impl FnMut(A, A) -> A,
) -> A {
    let threads = pol.threads.max(1);
    let chunk = pol.chunk.max(1);
    // one admission charge per delivered task, on every path below
    let body = |acc: &mut A, ctx: &WorkerCtx<'_>, task: Task| {
        if let Some(g) = ctx.gov {
            if !g.admit() {
                return;
            }
        }
        body(acc, ctx, task);
    };
    if threads == 1 || n <= chunk {
        let mut acc = init();
        if n > 0 {
            let ctx = WorkerCtx { worker: 0, pool: None, gov };
            match gov {
                None => body(&mut acc, &ctx, Task::Roots { start: 0, end: n }),
                Some(g) => {
                    // chunked so deadlines/budgets trip mid-run even on
                    // one thread; panic isolation must hold at
                    // `threads == 1` too (the governance suite sweeps
                    // the full thread matrix)
                    let run = catch_unwind(AssertUnwindSafe(|| {
                        let mut start = 0usize;
                        while start < n {
                            if g.is_cancelled() {
                                break;
                            }
                            let end = start.saturating_add(chunk).min(n);
                            body(&mut acc, &ctx, Task::Roots { start, end });
                            start = end;
                        }
                    }));
                    if let Err(payload) = run {
                        g.note_panic(panic_message(payload.as_ref()));
                    }
                }
            }
        }
        return acc;
    }
    if !pol.steal {
        return cursor_reduce(n, threads, chunk, gov, &init, &body, merge);
    }
    let pool = Pool::new(n, pol);
    // capture the caller's trace for re-install inside each worker
    // (thread-locals do not cross the scope boundary — PR 9)
    let trace = qtrace::current();
    let results: Vec<A> = sthread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                let pool = &pool;
                let init = &init;
                let body = &body;
                let trace = trace.clone();
                scope.spawn(move || {
                    qtrace::with_optional(trace, || worker_loop(pool, w, gov, init, body))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let result = fold(results, merge);
    let payload = pool.panic_payload.lock().unwrap_or_else(|e| e.into_inner()).take();
    if let Some(payload) = payload {
        resume_unwind(payload);
    }
    result
}

/// Side-effect-only companion to [`reduce`]: run `f(worker, index)`
/// for every index in `0..n` exactly once.
pub fn for_each(n: usize, pol: &SchedPolicy, f: impl Fn(usize, usize) + Sync) {
    reduce(
        n,
        pol,
        || (),
        |_, ctx, task| match task {
            Task::Roots { start, end } => {
                for i in start..end {
                    f(ctx.worker, i);
                }
            }
            Task::Split { .. } => {
                unreachable!("index adapters never publish split tasks")
            }
        },
        |(), ()| (),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn sum_to(n: usize, pol: &SchedPolicy) -> u64 {
        reduce(
            n,
            pol,
            || 0u64,
            |acc, _, task| match task {
                Task::Roots { start, end } => {
                    for i in start..end {
                        *acc += i as u64;
                    }
                }
                Task::Split { .. } => unreachable!("no splits published"),
            },
            |a, b| a + b,
        )
    }

    #[test]
    fn reduce_matches_closed_form_across_policies() {
        let n = 10_000usize;
        let want = (n as u64 - 1) * n as u64 / 2;
        for threads in [1usize, 2, 3, 8] {
            for steal in [false, true] {
                for shards in [1usize, 2, 4, 16] {
                    for chunk in [1usize, 7, 64, usize::MAX] {
                        let pol = SchedPolicy { threads, chunk, steal, shards };
                        assert_eq!(
                            sum_to(n, &pol),
                            want,
                            "threads={threads} steal={steal} shards={shards} chunk={chunk}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn every_index_runs_exactly_once_under_stealing() {
        let n = 4096usize;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let pol = SchedPolicy { threads: 8, chunk: 4, steal: true, shards: 3 };
        for_each(n, &pol, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_and_tiny_ranges() {
        let pol = SchedPolicy { threads: 4, chunk: 16, steal: true, shards: 2 };
        assert_eq!(sum_to(0, &pol), 0);
        assert_eq!(sum_to(1, &pol), 0);
        assert_eq!(sum_to(3, &pol), 3);
        // more shards than threads or tasks is clamped, not a panic
        let wide = SchedPolicy { threads: 2, chunk: 1, steal: true, shards: 64 };
        assert_eq!(sum_to(5, &wide), 10);
    }

    #[test]
    fn split_protocol_is_inert_without_a_pool() {
        let ctx = WorkerCtx { worker: 0, pool: None, gov: None };
        assert!(!ctx.split_requested());
        assert!(!ctx.publish_split(0, 0, 10));
        assert!(!ctx.cancelled());
    }

    #[test]
    fn ungoverned_pool_panic_propagates_after_clean_join() {
        // pre-PR-6 this hung: the unwinding worker never decremented
        // `active`, so peers spun forever in the idle sweep. Now the
        // payload is caught, the pool joins, and the panic re-raises on
        // the caller thread.
        let pol = SchedPolicy { threads: 4, chunk: 1, steal: true, shards: 2 };
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            reduce(
                1024,
                &pol,
                || 0u64,
                |acc, _, task| {
                    if let Task::Roots { start, end } = task {
                        for i in start..end {
                            if i == 500 {
                                panic!("hook failure at root 500");
                            }
                            *acc += 1;
                        }
                    }
                },
                |a, b| a + b,
            )
        }));
        let payload = caught.expect_err("worker panic must propagate to the caller");
        assert_eq!(panic_message(payload.as_ref()), "hook failure at root 500");
    }

    #[test]
    fn governed_panic_is_recorded_not_propagated() {
        use crate::engine::budget::{Budget, CancelReason, Governor};
        for (threads, steal) in [(1usize, true), (4, true), (4, false)] {
            let gov = Governor::new(&Budget::default());
            let pol = SchedPolicy { threads, chunk: 1, steal, shards: 2 };
            let total = reduce_governed(
                512,
                &pol,
                Some(&gov),
                || 0u64,
                |acc, _, task| {
                    if let Task::Roots { start, end } = task {
                        for i in start..end {
                            if i == 100 {
                                panic!("governed hook failure");
                            }
                            *acc += 1;
                        }
                    }
                },
                |a, b| a + b,
            );
            // the run survives and merges; the governor holds the cause
            assert!(total < 512, "threads={threads} steal={steal}");
            assert_eq!(gov.cancelled(), Some(CancelReason::WorkerPanic));
        }
    }

    #[test]
    fn task_budget_bounds_delivered_tasks_on_every_path() {
        use crate::engine::budget::{Budget, CancelReason, Governor};
        for (threads, steal) in [(1usize, true), (4, true), (4, false)] {
            let budget = Budget { max_tasks: Some(8), ..Budget::default() };
            let gov = Governor::new(&budget);
            let pol = SchedPolicy { threads, chunk: 4, steal, shards: 1 };
            let total = reduce_governed(
                100_000,
                &pol,
                Some(&gov),
                || 0u64,
                |acc, _, task| {
                    if let Task::Roots { start, end } = task {
                        *acc += (end - start) as u64;
                    }
                },
                |a, b| a + b,
            );
            // ≤ 8 admitted tasks × ≤ block-grain roots each, far below n
            assert!(total < 100_000, "threads={threads} steal={steal} total={total}");
            assert_eq!(gov.cancelled(), Some(CancelReason::TaskBudget));
        }
    }

    #[test]
    fn unlimited_governor_changes_nothing() {
        use crate::engine::budget::{Budget, Governor};
        let n = 10_000usize;
        let want = (n as u64 - 1) * n as u64 / 2;
        for steal in [false, true] {
            let gov = Governor::new(&Budget::default());
            let pol = SchedPolicy { threads: 4, chunk: 16, steal, shards: 2 };
            let got = reduce_governed(
                n,
                &pol,
                Some(&gov),
                || 0u64,
                |acc, _, task| {
                    if let Task::Roots { start, end } = task {
                        for i in start..end {
                            *acc += i as u64;
                        }
                    }
                },
                |a, b| a + b,
            );
            assert_eq!(got, want, "steal={steal}");
            assert_eq!(gov.cancelled(), None);
        }
    }

    #[test]
    fn published_splits_are_delivered_back_to_the_body() {
        // Body protocol: each root contributes 1 per "candidate"; root 0
        // has 64 candidates and publishes its suffix whenever the gate
        // asks. Whether or not splits fire (timing-dependent), the total
        // must equal the sequential answer — and split tasks, when they
        // do arrive, must carry a sane window.
        let n = 256usize;
        let candidates = 64usize;
        let pol = SchedPolicy { threads: 4, chunk: 1, steal: true, shards: 1 };
        let total = reduce(
            n,
            &pol,
            || 0u64,
            |acc, ctx, task| {
                let mut work = |root: usize, lo: usize, hi: usize| {
                    if root != 0 {
                        *acc += 1;
                        return;
                    }
                    let mut pos = lo;
                    let mut end = hi.min(candidates);
                    while pos < end {
                        if end - pos > 1 && ctx.split_requested() && ctx.publish_split(0, pos + 1, end)
                        {
                            end = pos + 1;
                        }
                        *acc += 1;
                        // make the hub root slow enough to starve peers
                        std::hint::black_box((0..500).sum::<u64>());
                        pos += 1;
                    }
                };
                match task {
                    Task::Roots { start, end } => {
                        for r in start..end {
                            work(r, 0, usize::MAX);
                        }
                    }
                    Task::Split { root, lo, hi } => {
                        assert_eq!(root, 0, "only root 0 publishes");
                        assert!(lo < hi && hi <= candidates);
                        work(root, lo, hi);
                    }
                }
            },
            |a, b| a + b,
        );
        assert_eq!(total, (n as u64 - 1) + candidates as u64);
    }

    #[test]
    fn overrides_are_scoped_and_nest() {
        let base = SchedPolicy::auto(4, 8);
        assert_eq!(base.steal, steal_enabled_default());
        with_overrides(Overrides { steal: Some(false), shards: Some(3) }, || {
            let p = SchedPolicy::auto(4, 8);
            assert!(!p.steal);
            assert_eq!(p.shards, 3);
            with_overrides(Overrides { steal: None, shards: Some(5) }, || {
                let q = SchedPolicy::auto(4, 8);
                assert_eq!(q.steal, steal_enabled_default());
                assert_eq!(q.shards, 5);
            });
            // inner scope restored
            assert_eq!(SchedPolicy::auto(4, 8).shards, 3);
        });
        let after = SchedPolicy::auto(4, 8);
        assert_eq!(after.shards, base.shards);
    }

    #[test]
    fn simultaneous_root_sets_are_isolated() {
        // the resident-service shape: several threads run reduce() at
        // once, each over its own root set with its own overrides; every
        // sum must be exact and no thread may observe a peer's overrides
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let ov = Overrides { steal: Some(i % 2 == 0), shards: Some(i + 1) };
                    with_overrides(ov, || {
                        let pol = SchedPolicy::auto(2, 3);
                        match ov.steal {
                            Some(false) => assert!(!pol.steal),
                            _ => assert_eq!(pol.steal, steal_enabled_default()),
                        }
                        assert_eq!(pol.shards, i + 1);
                        let n = 64 + i * 17;
                        let total = reduce(
                            n,
                            &pol,
                            || 0u64,
                            |acc, _, task| {
                                if let Task::Roots { start, end } = task {
                                    *acc += (start..end).map(|r| r as u64 + 1).sum::<u64>();
                                }
                            },
                            |a, b| a + b,
                        );
                        assert_eq!(total, (n as u64) * (n as u64 + 1) / 2);
                        // the run must not have perturbed this thread's
                        // own ambient overrides
                        assert_eq!(current_overrides(), ov);
                    });
                    // and the scope restores the default on the way out
                    assert_eq!(current_overrides(), Overrides::default());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
