//! Locality shard detection and task-space partitioning.
//!
//! On multi-socket hosts every cross-socket cache-line bounce costs an
//! order of magnitude more than an on-socket transfer, so the scheduler
//! partitions both the *workers* and the *root-task space* into
//! **shards** — one per NUMA node when the host exposes them. Workers
//! claim and steal inside their own shard first and cross the shard
//! boundary only once a whole shard has drained
//! ([`crate::exec::sched`] implements that policy; this module only
//! answers "how many shards, and who owns what").
//!
//! Shard count resolution, in priority order:
//!
//! 1. `SANDSLASH_SHARDS` — explicit override, same loud-reject parse
//!    contract as `SANDSLASH_THREADS` (an unusable value warns once on
//!    stderr and falls through, it is never silently applied).
//! 2. `/sys/devices/system/node/node<N>` directory count (Linux sysfs;
//!    the same source `numactl --hardware` reads).
//! 3. One shard — single-socket hosts and non-Linux platforms lose
//!    nothing: one shard is exactly the pre-PR-4 flat task space.
//!
//! The detected value is cached for the process lifetime (`OnceLock`),
//! so campaign loops never pay a sysfs walk per query. Per-run
//! overrides go through [`crate::engine::MinerConfig::with_shards`] or
//! [`crate::exec::sched::with_overrides`] instead of the environment.

use std::sync::OnceLock;

/// Where the process-wide shard count came from (recorded so bench
/// metadata and doctor output can say *why* a run was sharded).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardSource {
    /// `SANDSLASH_SHARDS` environment override.
    Env,
    /// Counted `node<N>` entries under `/sys/devices/system/node`.
    Sysfs,
    /// No usable signal — single flat shard.
    Fallback,
}

/// Process-wide shard topology (cached; see module docs for the
/// resolution order).
#[derive(Clone, Copy, Debug)]
pub struct Topology {
    /// Number of locality shards (≥ 1).
    pub shards: usize,
    /// Which detection rule produced [`Topology::shards`].
    pub source: ShardSource,
}

/// Resolve (once) and return the process-wide topology.
pub fn detect() -> Topology {
    static CACHE: OnceLock<Topology> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Some(n) =
            crate::util::pool::positive_usize_env("SANDSLASH_SHARDS", "the detected node count")
        {
            return Topology { shards: n, source: ShardSource::Env };
        }
        match sysfs_node_count() {
            Some(n) if n > 0 => Topology { shards: n, source: ShardSource::Sysfs },
            _ => Topology { shards: 1, source: ShardSource::Fallback },
        }
    })
}

/// The process-wide default shard count (cached detection).
pub fn shards() -> usize {
    detect().shards
}

/// Count NUMA nodes the way the kernel reports them: `node<N>`
/// directories under `/sys/devices/system/node`.
fn sysfs_node_count() -> Option<usize> {
    let dir = std::fs::read_dir("/sys/devices/system/node").ok()?;
    let names = dir
        .flatten()
        .filter_map(|e| e.file_name().into_string().ok());
    Some(count_node_entries(names))
}

/// `node<digits>` name filter, split out of the sysfs walk so the parse
/// rule is unit-testable without a fake filesystem.
fn count_node_entries(names: impl Iterator<Item = String>) -> usize {
    names
        .filter(|name| {
            name.strip_prefix("node")
                .is_some_and(|rest| !rest.is_empty() && rest.bytes().all(|b| b.is_ascii_digit()))
        })
        .count()
}

/// Logical shard a worker is pinned to: round-robin, so every shard gets
/// a worker before any shard gets two (callers clamp `shards` to the
/// worker count first, which makes the pinning surjective).
pub fn shard_of(worker: usize, shards: usize) -> usize {
    worker % shards.max(1)
}

/// Contiguous slice of the root-task space `0..n` owned by `shard`:
/// `[shard*n/shards, (shard+1)*n/shards)`. The slices are disjoint,
/// cover `0..n` exactly, and differ in length by at most one task.
pub fn shard_range(shard: usize, shards: usize, n: usize) -> (usize, usize) {
    let shards = shards.max(1);
    debug_assert!(shard < shards);
    (shard * n / shards, (shard + 1) * n / shards)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_exactly() {
        for n in [0usize, 1, 7, 64, 1000, 1001] {
            for shards in [1usize, 2, 3, 8, 13] {
                let mut expect = 0usize;
                for s in 0..shards {
                    let (lo, hi) = shard_range(s, shards, n);
                    assert_eq!(lo, expect, "n={n} shards={shards} s={s}");
                    assert!(hi >= lo);
                    // balanced to within one task
                    assert!(hi - lo <= n / shards + 1);
                    expect = hi;
                }
                assert_eq!(expect, n, "n={n} shards={shards}");
            }
        }
    }

    #[test]
    fn shard_of_covers_every_shard() {
        for shards in [1usize, 2, 4] {
            let workers = shards * 3;
            let mut seen = vec![false; shards];
            for w in 0..workers {
                let s = shard_of(w, shards);
                assert!(s < shards);
                seen[s] = true;
            }
            assert!(seen.iter().all(|&x| x), "shards={shards}");
        }
        // degenerate inputs never divide by zero
        assert_eq!(shard_of(5, 0), 0);
    }

    #[test]
    fn node_entry_filter_matches_kernel_layout() {
        let names = [
            "node0", "node1", "node12", // real nodes
            "node", "nodex", "node1a", "cpumap", "has_cpu", "online",
        ];
        let n = count_node_entries(names.iter().map(|s| s.to_string()));
        assert_eq!(n, 3);
        assert_eq!(count_node_entries(std::iter::empty()), 0);
    }

    #[test]
    fn detection_is_cached_and_positive() {
        let a = detect();
        let b = detect();
        assert!(a.shards >= 1);
        assert_eq!(a.shards, b.shards);
        assert_eq!(a.source, b.source);
    }
}
