//! Adaptive subtree splitting — the demand signal between starving and
//! loaded workers.
//!
//! Range stealing (deques of root ranges, [`crate::exec::sched`])
//! balances load only down to the granularity of *one root task*. On
//! power-law graphs that is not enough: a single hub root can carry a
//! constant fraction of the whole search tree, so the worker that drew
//! it serializes the tail of the run while every other worker idles.
//! The paper's answer (and Peregrine's fine-grained matching tasks) is
//! to split *inside* the root task: the untraversed suffix of the
//! root's level-1 candidate set is itself a perfectly good task list.
//!
//! The protocol is demand-driven so the common case (no starvation)
//! costs one relaxed load per level-1 candidate and nothing else:
//!
//! 1. A worker that finds no work anywhere **registers hunger** on the
//!    pool's [`SplitGate`] and keeps sweeping.
//! 2. A loaded worker polls [`SplitGate::requests_pending`] from its
//!    level-1 loop (via
//!    [`WorkerCtx::split_requested`](crate::exec::sched::WorkerCtx::split_requested)).
//!    When hunger is pending *and its own deque is empty* — if the
//!    deque still holds stealable ranges, thieves should take those
//!    first — it publishes the candidate suffix `[pos+1, end)` as a
//!    `Task::Split` on its own deque and truncates its own loop to the
//!    current candidate. The empty-deque condition doubles as flow
//!    control: at most one unstolen split per worker at a time.
//! 3. The hungry worker steals the published task like any other, and
//!    may split it again in turn — hub candidates fan out recursively,
//!    bounding the longest sequential chain by the split grain instead
//!    of the hub subtree.
//!
//! Hunger is a *level*, not an event: a worker deregisters only when it
//! acquires work (or exits at termination), so a loaded worker never
//! misses a request by polling late. Splits re-execute the root's
//! level-0 setup (root bitmap, sb bounds) — that is deliberate: the
//! setup is worker-local, deterministic, and orders of magnitude
//! cheaper than the subtree being handed away.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Count of currently-starving workers, shared by one scheduler pool.
///
/// Writes are rare (hunger edges), reads are one relaxed load on a
/// read-mostly line, so loaded workers can poll from the level-1 hot
/// loop without cross-core traffic in the steady state.
#[derive(Debug, Default)]
pub struct SplitGate {
    hungry: AtomicUsize,
}

impl SplitGate {
    /// A gate with no pending hunger.
    pub(crate) fn new() -> Self {
        Self::default()
    }

    /// A worker found no work anywhere; raise the demand level.
    pub(crate) fn register(&self) {
        self.hungry.fetch_add(1, Ordering::Relaxed);
    }

    /// A previously-hungry worker acquired work (or exited).
    pub(crate) fn deregister(&self) {
        let prev = self.hungry.fetch_sub(1, Ordering::Relaxed);
        debug_assert!(prev > 0, "split-gate hunger underflow");
    }

    /// Whether any worker is currently starving. Loaded workers poll
    /// this (cheap, read-mostly) to decide when publishing a split is
    /// worth the task-setup replay.
    pub fn requests_pending(&self) -> bool {
        self.hungry.load(Ordering::Relaxed) > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hunger_is_a_level_not_an_event() {
        let gate = SplitGate::new();
        assert!(!gate.requests_pending());
        gate.register();
        assert!(gate.requests_pending());
        gate.register();
        gate.deregister();
        // one worker still hungry
        assert!(gate.requests_pending());
        gate.deregister();
        assert!(!gate.requests_pending());
    }
}
