//! Adaptive subtree splitting — the demand signal between starving and
//! loaded workers.
//!
//! Range stealing (deques of root ranges, [`crate::exec::sched`])
//! balances load only down to the granularity of *one root task*. On
//! power-law graphs that is not enough: a single hub root can carry a
//! constant fraction of the whole search tree, so the worker that drew
//! it serializes the tail of the run while every other worker idles.
//! The paper's answer (and Peregrine's fine-grained matching tasks) is
//! to split *inside* the root task: the untraversed suffix of the
//! root's level-1 candidate set is itself a perfectly good task list.
//!
//! The protocol is demand-driven so the common case (no starvation)
//! costs one relaxed load per level-1 candidate and nothing else:
//!
//! 1. A worker that finds no work anywhere **registers hunger** on the
//!    pool's [`SplitGate`] and keeps sweeping.
//! 2. A loaded worker polls [`SplitGate::requests_pending`] from its
//!    level-1 loop (via
//!    [`WorkerCtx::split_requested`](crate::exec::sched::WorkerCtx::split_requested)).
//!    When hunger is pending *and its own deque is empty* — if the
//!    deque still holds stealable ranges, thieves should take those
//!    first — it publishes the candidate suffix `[pos+1, end)` as a
//!    `Task::Split` on its own deque and truncates its own loop to the
//!    current candidate. The empty-deque condition doubles as flow
//!    control: at most one unstolen split per worker at a time.
//! 3. The hungry worker steals the published task like any other, and
//!    may split it again in turn — hub candidates fan out recursively,
//!    bounding the longest sequential chain by the split grain instead
//!    of the hub subtree.
//!
//! Hunger is a *level*, not an event: a worker deregisters only when it
//! acquires work (or exits at termination), so a loaded worker never
//! misses a request by polling late. Splits re-execute the root's
//! level-0 setup (root bitmap, sb bounds, FSM child regeneration) —
//! that is deliberate: the setup is worker-local, deterministic, and
//! orders of magnitude cheaper than the subtree being handed away.
//!
//! # The `Splittable` root-task contract (PR 5)
//!
//! Originally the window + publish + truncate discipline was hard-coded
//! into `dfs::mine_root`; it is now a reusable pair any engine adopts:
//!
//! * [`Splittable`] — an engine whose root task's level-1 work is a
//!   *deterministic sequence of independent positions*. The engine
//!   implements [`Splittable::mine_root`]; [`reduce`] maps scheduler
//!   tasks onto it (whole roots get `window = None`, a [`Task::Split`]
//!   re-enters with the published `[lo, hi)` position window).
//! * [`SplitDriver`] — the level-1 polling loop: an iterator over the
//!   windowed positions that, before yielding each one, checks
//!   [`WorkerCtx::split_requested`] and hands the untraversed suffix to
//!   a starving worker.
//!
//! Three engines ride this today: the set-centric DFS (level-1
//! candidate positions), ESU (level-1 extension-set positions), and FSM
//! (frequent-children positions of a root pattern bin). In every case
//! the sequence must be a pure function of (root, input, config), so a
//! replayed setup lands on exactly the positions the publisher was
//! iterating — and any root-level accounting must be done only by the
//! `window = None` task, which is the sole task guaranteed to run the
//! setup exactly once per root across the whole run.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::engine::budget::Governor;
use crate::util::fault;

use super::sched::{self, SchedPolicy, Task, WorkerCtx};

/// Count of currently-starving workers, shared by one scheduler pool.
///
/// Writes are rare (hunger edges), reads are one relaxed load on a
/// read-mostly line, so loaded workers can poll from the level-1 hot
/// loop without cross-core traffic in the steady state.
#[derive(Debug, Default)]
pub struct SplitGate {
    hungry: AtomicUsize,
}

impl SplitGate {
    /// A gate with no pending hunger.
    pub(crate) fn new() -> Self {
        Self::default()
    }

    /// A worker found no work anywhere; raise the demand level.
    pub(crate) fn register(&self) {
        self.hungry.fetch_add(1, Ordering::Relaxed);
    }

    /// A previously-hungry worker acquired work (or exited).
    pub(crate) fn deregister(&self) {
        let prev = self.hungry.fetch_sub(1, Ordering::Relaxed);
        debug_assert!(prev > 0, "split-gate hunger underflow");
    }

    /// Whether any worker is currently starving. Loaded workers poll
    /// this (cheap, read-mostly) to decide when publishing a split is
    /// worth the task-setup replay.
    pub fn requests_pending(&self) -> bool {
        self.hungry.load(Ordering::Relaxed) > 0
    }
}

/// A mining engine whose root tasks obey the split contract (module
/// docs): the root's level-1 work is a deterministic sequence of
/// independent positions, and `mine_root` can execute any window of it.
pub trait Splittable: Sync {
    /// Per-worker accumulator/state threaded through one run.
    type Acc;

    /// Execute root `root` restricted to `window` over its level-1
    /// sequence. `None` is the whole root — the only call that runs
    /// per-root accounting; `Some((lo, hi))` is a published suffix
    /// re-entering the deterministic sequence (setup replayed, stats
    /// quiet, positions `[lo, hi)` only).
    fn mine_root(
        &self,
        acc: &mut Self::Acc,
        ctx: &WorkerCtx<'_>,
        root: usize,
        window: Option<(usize, usize)>,
    );
}

/// Parallel reduce over the roots `0..n` of a [`Splittable`] engine:
/// the one `Task` match shared by every split-aware engine (previously
/// hard-coded into `dfs::mine`). Whole-root ranges fan out position by
/// position; published [`Task::Split`] windows are delivered back to
/// the same engine body.
///
/// The optional [`Governor`] (PR 6) is threaded to
/// [`sched::reduce_governed`], which charges each delivered task
/// against the run's budget; between roots of one range the body polls
/// [`WorkerCtx::cancelled`] (one relaxed load) so a trip stops the run
/// within one root, not one block. Both task arms carry a named
/// fault-injection point ([`fault::Stage::RootClaim`] /
/// [`fault::Stage::SplitTask`]) for the governance suite.
pub fn reduce<S>(
    n: usize,
    pol: &SchedPolicy,
    engine: &S,
    gov: Option<&Governor>,
    init: impl Fn() -> S::Acc + Sync,
    merge: impl FnMut(S::Acc, S::Acc) -> S::Acc,
) -> S::Acc
where
    S: Splittable,
    S::Acc: Send,
{
    sched::reduce_governed(
        n,
        pol,
        gov,
        init,
        |acc, ctx, task| match task {
            Task::Roots { start, end } => {
                fault::point(fault::Stage::RootClaim);
                for root in start..end {
                    if ctx.cancelled() {
                        break;
                    }
                    engine.mine_root(acc, ctx, root, None);
                }
            }
            Task::Split { root, lo, hi } => {
                fault::point(fault::Stage::SplitTask);
                engine.mine_root(acc, ctx, root, Some((lo, hi)));
            }
        },
        merge,
    )
}

/// The level-1 polling loop of the split protocol, shared by every
/// publisher so the window + publish + truncate discipline cannot drift
/// between engines: iterates the candidate positions of one root task
/// clamped to its window, and before yielding each position — when a
/// worker is starving and this worker's own deque is empty — publishes
/// the untraversed suffix `[pos + 1, end)` as a [`Task::Split`] and
/// keeps only the current position for itself.
pub struct SplitDriver<'a, 'p> {
    ctx: &'a WorkerCtx<'p>,
    root: usize,
    pos: usize,
    end: usize,
}

impl<'a, 'p> SplitDriver<'a, 'p> {
    /// Driver over the `len` level-1 positions of `root`, clamped to
    /// `window` (a [`Task::Split`] suffix) when present.
    pub fn new(
        ctx: &'a WorkerCtx<'p>,
        root: usize,
        len: usize,
        window: Option<(usize, usize)>,
    ) -> Self {
        let (lo, hi) = window.unwrap_or((0, usize::MAX));
        Self { ctx, root, pos: lo.min(len), end: hi.min(len) }
    }
}

impl Iterator for SplitDriver<'_, '_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        if self.pos >= self.end {
            return None;
        }
        // the governance poll site: one relaxed load per level-1
        // candidate, exactly where the split gate already polls
        if self.ctx.cancelled() {
            self.pos = self.end;
            return None;
        }
        if self.end - self.pos > 1
            && self.ctx.split_requested()
            && self.ctx.publish_split(self.root, self.pos + 1, self.end)
        {
            self.end = self.pos + 1;
        }
        let p = self.pos;
        self.pos += 1;
        Some(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hunger_is_a_level_not_an_event() {
        let gate = SplitGate::new();
        assert!(!gate.requests_pending());
        gate.register();
        assert!(gate.requests_pending());
        gate.register();
        gate.deregister();
        // one worker still hungry
        assert!(gate.requests_pending());
        gate.deregister();
        assert!(!gate.requests_pending());
    }

    /// Toy splittable engine: root 0 carries `hub` level-1 positions,
    /// every other root exactly one; the accumulator counts positions.
    struct Toy {
        hub: usize,
        spin: u64,
    }

    impl Splittable for Toy {
        type Acc = u64;

        fn mine_root(
            &self,
            acc: &mut u64,
            ctx: &WorkerCtx<'_>,
            root: usize,
            window: Option<(usize, usize)>,
        ) {
            let len = if root == 0 { self.hub } else { 1 };
            if let Some((lo, hi)) = window {
                assert!(lo < hi && hi <= len, "split window out of range");
            }
            for _pos in SplitDriver::new(ctx, root, len, window) {
                // make the hub grind long enough to starve peers
                std::hint::black_box((0..self.spin).sum::<u64>());
                *acc += 1;
            }
        }
    }

    #[test]
    fn splittable_reduce_counts_each_position_once_across_policies() {
        let n = 256usize;
        let toy = Toy { hub: 64, spin: 500 };
        let want = (n as u64 - 1) + 64;
        for threads in [1usize, 4] {
            for steal in [false, true] {
                for shards in [1usize, 2] {
                    let pol = SchedPolicy { threads, chunk: 1, steal, shards };
                    let got = reduce(n, &pol, &toy, None, || 0u64, |a, b| a + b);
                    assert_eq!(got, want, "threads={threads} steal={steal} shards={shards}");
                }
            }
        }
    }

    #[test]
    fn driver_without_a_pool_walks_the_full_window_inline() {
        // sequential runs hand the body an inert ctx: the driver must
        // degrade to a plain loop and never publish
        let toy = Toy { hub: 10, spin: 0 };
        let pol = SchedPolicy { threads: 1, chunk: usize::MAX, steal: true, shards: 1 };
        let got = reduce(3, &pol, &toy, None, || 0u64, |a, b| a + b);
        assert_eq!(got, 12);
    }
}
