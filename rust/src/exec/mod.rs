//! Execution subsystem: the work-stealing, locality-sharded scheduler
//! (PR 4) that every mining engine fans its root tasks through.
//!
//! * [`sched`] — per-worker bounded deques (LIFO local pops, FIFO
//!   randomized-victim steals), lazy range halving, the cursor oracle,
//!   and the `reduce`/`for_each` entry points.
//! * [`topology`] — locality shard detection (`/sys/devices/system/node`,
//!   `SANDSLASH_SHARDS` override) and the worker/task-space partition.
//! * [`split`] — the demand-driven subtree-splitting protocol that
//!   breaks hub-rooted level-1 candidate sets into stealable tasks,
//!   plus (PR 5) the [`split::Splittable`] root-task contract and the
//!   [`split::SplitDriver`] polling loop that the DFS, ESU and FSM
//!   engines all publish through.
//!
//! The legacy `util::pool` entry points survive as thin adapters over
//! [`sched`], so engine and app call sites kept their signatures; new
//! code that wants scheduling control (split publication, per-run
//! policies) calls [`sched::reduce`] directly, as `engine::dfs` does.
//! `SANDSLASH_NO_STEAL=1` pins the whole process to the cursor oracle.

pub mod sched;
pub mod split;
pub mod topology;
