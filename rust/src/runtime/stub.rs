//! Stub accelerator used when the crate is built without the `xla`
//! feature. The real PJRT path ([`super`]'s `accel`/`pjrt` modules with
//! the feature on) needs the external `xla` and `anyhow` crates, which
//! the offline build environment does not provide; this keeps the same
//! API surface so callers compile unchanged, with every entry point
//! reporting that the runtime is unavailable.

use crate::engine::MinerConfig;
use crate::graph::CsrGraph;
use std::fmt;

/// Error carried by every stub entry point.
#[derive(Debug, Clone, Copy)]
pub struct AccelUnavailable;

impl fmt::Display for AccelUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT runtime unavailable: built without the `xla` feature \
             (requires vendored `xla` + `anyhow` crates)"
        )
    }
}

impl std::error::Error for AccelUnavailable {}

/// Result alias matching the real runtime's `anyhow::Result`.
pub type Result<T> = std::result::Result<T, AccelUnavailable>;

/// Same surface as the real `runtime::accel::Accelerator`.
pub struct Accelerator {
    /// Mirrors the real accelerator's batch width.
    pub edge_lanes: usize,
}

impl Accelerator {
    /// Always fails: the runtime is compiled out.
    pub fn load(_dir: &str) -> Result<Self> {
        Err(AccelUnavailable)
    }

    /// Placeholder platform name.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Always fails: the runtime is compiled out.
    pub fn triangle_count(&self, _g: &CsrGraph) -> Result<u64> {
        Err(AccelUnavailable)
    }

    /// Always fails: the runtime is compiled out.
    pub fn motif4(&self, _g: &CsrGraph, _cfg: &MinerConfig) -> Result<Vec<u64>> {
        Err(AccelUnavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_unavailable() {
        let err = Accelerator::load("artifacts").err().expect("stub must fail");
        assert!(format!("{err:#}").contains("xla"));
    }
}
