//! Dense-tile extraction: CSR graph -> 128x128 f32 adjacency tiles for
//! the AOT-compiled Pallas counting kernels.
//!
//! The Rust side supplies the sparsity-awareness the dense MXU path
//! lacks: vertices are degree-sorted (hubs first, concentrating mass in
//! the top-left tiles), all-zero tiles are skipped, and the runtime only
//! dispatches tile triples whose three factors are all non-empty.

use crate::graph::builder::{degree_desc_order, relabel};
use crate::graph::orientation::{orient, OrientScheme};
use crate::graph::CsrGraph;

/// Tile side length (matches the Pallas kernel block shape).
pub const TILE: usize = 128;

/// A blocked dense view of (an orientation of) the adjacency matrix.
pub struct TiledAdjacency {
    /// Grid dimension: number of tiles per side.
    pub grid: usize,
    /// Row-major tile pointers; `None` = all-zero tile (skipped).
    tiles: Vec<Option<Box<[f32]>>>,
    /// Vertex count after degree-sorted relabeling.
    pub num_vertices: usize,
    /// Number of materialized (non-empty) tiles.
    pub nonzero_tiles: usize,
}

impl TiledAdjacency {
    /// Build from a graph. `oriented` = use the degree DAG (upper
    /// triangle; exact triangle counts with no over-count); otherwise
    /// the full symmetric adjacency.
    pub fn build(g: &CsrGraph, oriented: bool) -> Self {
        // degree-sort so hubs cluster in low tile indices
        let perm = degree_desc_order(g);
        let h = relabel(g, &perm);
        let n = h.num_vertices();
        let grid = n.div_ceil(TILE);
        let mut tiles: Vec<Option<Box<[f32]>>> = (0..grid * grid).map(|_| None).collect();
        let mut set = |r: usize, c: usize, tiles: &mut Vec<Option<Box<[f32]>>>| {
            let (tr, tc) = (r / TILE, c / TILE);
            let t = tiles[tr * grid + tc]
                .get_or_insert_with(|| vec![0f32; TILE * TILE].into_boxed_slice());
            t[(r % TILE) * TILE + (c % TILE)] = 1.0;
        };
        if oriented {
            let dag = orient(&h, OrientScheme::Degree);
            for v in 0..n as u32 {
                for &u in dag.out_neighbors(v) {
                    set(v as usize, u as usize, &mut tiles);
                }
            }
        } else {
            for v in 0..n as u32 {
                for &u in h.neighbors(v) {
                    set(v as usize, u as usize, &mut tiles);
                }
            }
        }
        let nonzero = tiles.iter().filter(|t| t.is_some()).count();
        Self { grid, tiles, num_vertices: n, nonzero_tiles: nonzero }
    }

    #[inline]
    /// Tile at grid position (r, c); `None` = all-zero.
    pub fn tile(&self, r: usize, c: usize) -> Option<&[f32]> {
        self.tiles[r * self.grid + c].as_deref()
    }

    /// Non-empty (i, k, j) triples for the masked-matmul reduction
    /// Σ (A_ik @ A_kj) ⊙ A_ij.
    pub fn triples(&self) -> Vec<(usize, usize, usize)> {
        let mut out = Vec::new();
        for i in 0..self.grid {
            for k in 0..self.grid {
                if self.tile(i, k).is_none() {
                    continue;
                }
                for j in 0..self.grid {
                    if self.tile(k, j).is_some() && self.tile(i, j).is_some() {
                        out.push((i, k, j));
                    }
                }
            }
        }
        out
    }

    /// CPU reference for the tiled reduction (used to cross-check the
    /// PJRT path and as the fallback when artifacts are absent).
    pub fn masked_trace_cpu(&self) -> f64 {
        let mut total = 0f64;
        for (i, k, j) in self.triples() {
            let (x, y, m) = (
                self.tile(i, k).unwrap(),
                self.tile(k, j).unwrap(),
                self.tile(i, j).unwrap(),
            );
            for r in 0..TILE {
                for c in 0..TILE {
                    if m[r * TILE + c] == 0.0 {
                        continue;
                    }
                    let mut acc = 0f32;
                    for t in 0..TILE {
                        acc += x[r * TILE + t] * y[t * TILE + c];
                    }
                    total += acc as f64;
                }
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::tc::tc_hi;
    use crate::engine::{MinerConfig, OptFlags};
    use crate::graph::gen;

    #[test]
    fn tiled_trace_counts_triangles() {
        let g = gen::erdos_renyi(300, 0.05, 3, &[]);
        let tiled = TiledAdjacency::build(&g, true);
        let cfg = MinerConfig::custom(2, 16, OptFlags::hi());
        let want = tc_hi(&g, &cfg) as f64;
        assert_eq!(tiled.masked_trace_cpu(), want);
    }

    #[test]
    fn degree_sort_concentrates_mass() {
        let g = gen::rmat(9, 6, 5, &[]);
        let tiled = TiledAdjacency::build(&g, true);
        // tile (0,0) hosts the hub-hub block; it must be non-empty while
        // plenty of far tiles are empty
        assert!(tiled.tile(0, 0).is_some());
        assert!(tiled.nonzero_tiles < tiled.grid * tiled.grid);
    }

    #[test]
    fn triples_all_nonempty() {
        let g = gen::erdos_renyi(260, 0.03, 11, &[]);
        let tiled = TiledAdjacency::build(&g, true);
        for (i, k, j) in tiled.triples() {
            assert!(tiled.tile(i, k).is_some());
            assert!(tiled.tile(k, j).is_some());
            assert!(tiled.tile(i, j).is_some());
        }
    }
}
