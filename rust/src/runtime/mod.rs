//! PJRT runtime: load AOT-compiled HLO artifacts (Layer 1/2 outputs) and
//! execute them from the Rust hot path. Python never runs at mining
//! time — `make artifacts` is strictly build-time.

pub mod accel;
pub mod pjrt;
pub mod tiles;
