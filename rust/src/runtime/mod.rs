//! PJRT runtime: load AOT-compiled HLO artifacts (Layer 1/2 outputs) and
//! execute them from the Rust hot path. Python never runs at mining
//! time — `make artifacts` is strictly build-time.
//!
//! The PJRT client needs the external `xla` + `anyhow` crates, which are
//! not in the offline registry; they are gated behind the `xla` feature
//! (see Cargo.toml). Default builds get [`stub`] under the `accel` name:
//! the identical API surface with every entry point returning an
//! "unavailable" error, so the CLI and tests compile and degrade
//! gracefully.

pub mod tiles;

#[cfg(feature = "xla")]
pub mod accel;
#[cfg(feature = "xla")]
pub mod pjrt;

#[cfg(not(feature = "xla"))]
pub mod stub;
#[cfg(not(feature = "xla"))]
/// Stub-backed `accel` alias so callers compile without the `xla`
/// feature (see [`stub`]).
pub mod accel {
    pub use super::stub::*;
}
