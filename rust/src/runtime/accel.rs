//! The accelerated counting path: Rust coordinator -> PJRT -> AOT
//! Pallas kernels.
//!
//! Loads the HLO-text artifacts produced by `make artifacts`
//! (python/compile/aot.py) once, compiles them on the PJRT CPU client,
//! and streams dense adjacency tiles through them:
//!
//! * `tc_tile`:  Σ over (i,k,j) of sum((U_ik @ U_kj) ⊙ U_ij) = exact
//!   triangle count on the oriented tiling.
//! * `cn_tile`:  per-edge common-neighbour tiles -> local triangle
//!   counts for formula-based 4-motif counting.
//! * `motif_formulas`: batched Listing-3 local-count lanes.
//!
//! Python never runs here: artifacts are self-contained HLO text.

use anyhow::{Context, Result};

use crate::graph::CsrGraph;

use super::pjrt::Runtime;
use super::tiles::{TiledAdjacency, TILE};

/// Compiled Pallas kernels plus the PJRT runtime that executes them.
pub struct Accelerator {
    rt: Runtime,
    tc_tile: xla::PjRtLoadedExecutable,
    cn_tile: xla::PjRtLoadedExecutable,
    motif_formulas: xla::PjRtLoadedExecutable,
    /// Batch width for the per-edge formula lanes.
    pub edge_lanes: usize,
}

impl Accelerator {
    /// Load artifacts from the given directory (default: `artifacts/`).
    pub fn load(dir: &str) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let path = |n: &str| format!("{dir}/{n}.hlo.txt");
        let tc_tile = rt
            .load_hlo_text(&path("tc_tile"))
            .with_context(|| "loading tc_tile (run `make artifacts`)")?;
        let cn_tile = rt.load_hlo_text(&path("cn_tile"))?;
        let motif_formulas = rt.load_hlo_text(&path("motif_formulas"))?;
        Ok(Self { rt, tc_tile, cn_tile, motif_formulas, edge_lanes: 4096 })
    }

    /// Backend platform name reported by PJRT.
    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    fn lit(tile: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(tile).reshape(dims)?)
    }

    /// Exact triangle count via the tiled masked-matmul-trace kernel.
    pub fn triangle_count(&self, g: &CsrGraph) -> Result<u64> {
        let tiled = TiledAdjacency::build(g, true);
        let mut total = 0f64;
        let d = [TILE as i64, TILE as i64];
        for (i, k, j) in tiled.triples() {
            let x = Self::lit(tiled.tile(i, k).unwrap(), &d)?;
            let y = Self::lit(tiled.tile(k, j).unwrap(), &d)?;
            let m = Self::lit(tiled.tile(i, j).unwrap(), &d)?;
            let out = self.tc_tile.execute::<xla::Literal>(&[x, y, m])?[0][0]
                .to_literal_sync()?;
            let v = out.to_tuple1()?.to_vec::<f32>()?;
            total += v[0] as f64;
        }
        Ok(total as u64)
    }

    /// Per-edge local triangle counts for the whole (symmetric) tiling:
    /// returns the tiled CN matrix as (tile row, tile col, dense tile).
    pub fn common_neighbor_tiles(
        &self,
        tiled: &TiledAdjacency,
    ) -> Result<Vec<(usize, usize, Vec<f32>)>> {
        let d = [TILE as i64, TILE as i64];
        let grid = tiled.grid;
        let mut out = Vec::new();
        for i in 0..grid {
            for j in 0..grid {
                let Some(mask) = tiled.tile(i, j) else { continue };
                let mut acc = vec![0f32; TILE * TILE];
                let mut any = false;
                for k in 0..grid {
                    let (Some(x), Some(y)) = (tiled.tile(i, k), tiled.tile(k, j)) else {
                        continue;
                    };
                    let r = self
                        .cn_tile
                        .execute::<xla::Literal>(&[
                            Self::lit(x, &d)?,
                            Self::lit(y, &d)?,
                            Self::lit(mask, &d)?,
                        ])?[0][0]
                        .to_literal_sync()?;
                    let v = r.to_tuple1()?.to_vec::<f32>()?;
                    for (a, b) in acc.iter_mut().zip(v) {
                        *a += b;
                    }
                    any = true;
                }
                if any {
                    out.push((i, j, acc));
                }
            }
        }
        Ok(out)
    }

    /// Run the batched motif-formula kernel over per-edge statistics.
    /// Inputs are padded to `edge_lanes`; returns the 5 raw-count sums
    /// [Σ C(tri,2), Σ tri(s_u+s_v), Σ s_u·s_v, Σ star3-lane, Σ wedge-lane].
    pub fn motif_raw_sums(
        &self,
        tri: &[f32],
        deg_u: &[f32],
        deg_v: &[f32],
    ) -> Result<[f64; 5]> {
        assert_eq!(tri.len(), deg_u.len());
        assert_eq!(tri.len(), deg_v.len());
        let lanes = self.edge_lanes;
        let mut sums = [0f64; 5];
        let mut base = 0;
        while base < tri.len() {
            let n = lanes.min(tri.len() - base);
            let pad = |xs: &[f32]| -> Vec<f32> {
                let mut v = xs[base..base + n].to_vec();
                v.resize(lanes, 0.0);
                v
            };
            let valid: Vec<f32> = (0..lanes).map(|i| (i < n) as u32 as f32).collect();
            let args = [
                Self::lit(&pad(tri), &[lanes as i64])?,
                Self::lit(&pad(deg_u), &[lanes as i64])?,
                Self::lit(&pad(deg_v), &[lanes as i64])?,
                Self::lit(&valid, &[lanes as i64])?,
            ];
            let r = self.motif_formulas.execute::<xla::Literal>(&args)?[0][0]
                .to_literal_sync()?;
            let v = r.to_tuple1()?.to_vec::<f32>()?; // [5, lanes] row-major
            for row in 0..5 {
                sums[row] += v[row * lanes..(row + 1) * lanes]
                    .iter()
                    .map(|&x| x as f64)
                    .sum::<f64>();
            }
            base += n;
        }
        Ok(sums)
    }

    /// Accelerated 4-motif counting: per-edge triangle counts from the
    /// CN kernel, raw sums from the formula kernel, anchors (4-clique,
    /// induced 4-cycle) from the combinatorial engine, conversions on the
    /// CPU. Returns counts in all_motifs(4) order.
    pub fn motif4(&self, g: &CsrGraph, cfg: &crate::engine::MinerConfig) -> Result<Vec<u64>> {
        // per-edge statistics through the L1 kernels
        let (tri, du, dv) = per_edge_stats_via_kernels(self, g)?;
        let raw = self.motif_raw_sums(&tri, &du, &dv)?;
        let (raw_d, raw_tt, raw_p4) = (raw[0] as u64, raw[1] as u64, raw[2] as u64);
        // anchors via the combinatorial engine (governed: budget trips or
        // worker panics in the anchor mine surface as errors here)
        let (c4, _) = crate::apps::clique::clique_hi(g, 4, cfg);
        let pl = crate::pattern::plan(&crate::pattern::library::cycle(4), true, true);
        let (cy, _) = crate::engine::dfs::count(g, &pl, cfg, &crate::engine::hooks::NoHooks)?
            .into_parts();
        let raw_s3: u64 = (0..g.num_vertices() as u32)
            .map(|v| {
                let d = g.degree(v) as u64;
                if d >= 3 {
                    d * (d - 1) * (d - 2) / 6
                } else {
                    0
                }
            })
            .sum();
        let d = raw_d - 6 * c4;
        let tt = (raw_tt - 4 * d) / 2;
        let p4 = raw_p4 - 4 * cy;
        let s3 = raw_s3 - tt - 2 * d - 4 * c4;
        Ok(vec![s3, p4, tt, cy, d, c4])
    }
}

/// Per-edge (tri, deg_u, deg_v) for all undirected edges, computing tri
/// through the CN tile kernel on the symmetric tiling.
fn per_edge_stats_via_kernels(
    acc: &Accelerator,
    g: &CsrGraph,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    use crate::graph::builder::{degree_desc_order, relabel};
    let perm = degree_desc_order(g);
    let h = relabel(g, &perm);
    let tiled = TiledAdjacency::build(g, false); // tiles of h, symmetric
    let cn = acc.common_neighbor_tiles(&tiled)?;
    // index CN tiles for lookup
    let grid = tiled.grid;
    let mut cn_map: Vec<Option<Vec<f32>>> = (0..grid * grid).map(|_| None).collect();
    for (i, j, t) in cn {
        cn_map[i * grid + j] = Some(t);
    }
    let mut tri = Vec::new();
    let mut du = Vec::new();
    let mut dv = Vec::new();
    for (u, v) in h.edges() {
        let (r, c) = (u as usize, v as usize);
        let t = cn_map[(r / TILE) * grid + c / TILE]
            .as_ref()
            .map(|t| t[(r % TILE) * TILE + (c % TILE)])
            .unwrap_or(0.0);
        tri.push(t);
        du.push(h.degree(u) as f32);
        dv.push(h.degree(v) as f32);
    }
    Ok((tri, du, dv))
}
