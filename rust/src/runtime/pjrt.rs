//! Thin wrapper over the `xla` crate's PJRT CPU client.
use anyhow::Result;

pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
    pub fn load_hlo_text(&self, path: &str) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}
