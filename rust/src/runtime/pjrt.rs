//! Thin wrapper over the `xla` crate's PJRT CPU client.
use anyhow::Result;

/// Owned PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU-backed PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }
    /// Backend platform name reported by PJRT.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
    /// Compile an HLO-text artifact into a loaded executable.
    pub fn load_hlo_text(&self, path: &str) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}
