//! Graph IO: edge-list text (optionally labeled) and a binary CSR
//! snapshot format for fast reloads of generated benchmark inputs.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::builder::GraphBuilder;
use super::csr::{CsrGraph, VertexId};

/// Load a whitespace-separated edge list: `u v` per line, `#` comments.
/// Vertex ids are assigned densely from the raw ids encountered.
pub fn load_edge_list(path: &Path) -> std::io::Result<CsrGraph> {
    let f = std::fs::File::open(path)?;
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut max_v: VertexId = 0;
    for line in BufReader::new(f).lines() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let (u, v) = (
            parse_id(it.next(), path)?,
            parse_id(it.next(), path)?,
        );
        max_v = max_v.max(u).max(v);
        edges.push((u, v));
    }
    Ok(GraphBuilder::from_edges(max_v as usize + 1, &edges).build())
}

/// Load a labeled graph: lines `v <label>` in a `# labels` section follow
/// the edge lines, or a companion `<path>.labels` file with one label per
/// vertex line.
pub fn load_labels(path: &Path, n: usize) -> std::io::Result<Vec<u32>> {
    let f = std::fs::File::open(path)?;
    let mut labels = vec![0u32; n];
    for (i, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || i >= n {
            continue;
        }
        labels[i] = line.parse().map_err(bad_data)?;
    }
    Ok(labels)
}

fn parse_id(tok: Option<&str>, path: &Path) -> std::io::Result<VertexId> {
    tok.ok_or_else(|| bad_data(format!("{path:?}: missing vertex id")))?
        .parse()
        .map_err(bad_data)
}

fn bad_data<E: std::fmt::Display>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

/// Save an edge list (undirected edges once, u < v).
pub fn save_edge_list(g: &CsrGraph, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

const SNAPSHOT_MAGIC: u64 = 0x53_41_4E_44_43_53_52_31; // "SANDCSR1"

/// Binary snapshot: magic, n, m, has_labels, offsets, neighbors, labels.
pub fn save_snapshot(g: &CsrGraph, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    let n = g.num_vertices() as u64;
    let m = g.neighbors.len() as u64;
    let has_labels = g.is_labeled() as u64;
    for x in [SNAPSHOT_MAGIC, n, m, has_labels] {
        w.write_all(&x.to_le_bytes())?;
    }
    for &o in &g.offsets {
        w.write_all(&o.to_le_bytes())?;
    }
    for &v in &g.neighbors {
        w.write_all(&v.to_le_bytes())?;
    }
    for &l in &g.labels {
        w.write_all(&l.to_le_bytes())?;
    }
    Ok(())
}

/// Load a binary CSR snapshot produced by the save path.
pub fn load_snapshot(path: &Path) -> std::io::Result<CsrGraph> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let magic = read_u64(&mut r)?;
    if magic != SNAPSHOT_MAGIC {
        return Err(bad_data("not a sandslash CSR snapshot"));
    }
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    let has_labels = read_u64(&mut r)? != 0;
    let mut offsets = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        offsets.push(read_u64(&mut r)?);
    }
    let mut neighbors = Vec::with_capacity(m);
    for _ in 0..m {
        neighbors.push(read_u32(&mut r)?);
    }
    let mut labels = Vec::new();
    if has_labels {
        labels.reserve(n);
        for _ in 0..n {
            labels.push(read_u32(&mut r)?);
        }
    }
    Ok(CsrGraph { offsets, neighbors, labels })
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("sandslash_io_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = gen::erdos_renyi(50, 0.2, 7, &[]);
        let path = tmp("el.txt");
        save_edge_list(&g, &path).unwrap();
        let h = load_edge_list(&path).unwrap();
        assert_eq!(g.num_undirected_edges(), h.num_undirected_edges());
        for (u, v) in g.edges() {
            assert!(h.has_edge(u, v));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn snapshot_roundtrip_labeled() {
        let g = gen::erdos_renyi(40, 0.15, 9, &[0, 1, 2]);
        let path = tmp("snap.bin");
        save_snapshot(&g, &path).unwrap();
        let h = load_snapshot(&path).unwrap();
        assert_eq!(g.offsets, h.offsets);
        assert_eq!(g.neighbors, h.neighbors);
        assert_eq!(g.labels, h.labels);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad.bin");
        std::fs::write(&path, [0u8; 64]).unwrap();
        assert!(load_snapshot(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn edge_list_with_comments() {
        let path = tmp("comments.el");
        std::fs::write(&path, "# header\n0 1\n1 2 # trailing\n\n2 0\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.num_undirected_edges(), 3);
        std::fs::remove_file(path).ok();
    }
}
