//! Graph IO: edge-list text (optionally labeled) and a binary CSR
//! snapshot format for fast reloads of generated benchmark inputs.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::builder::GraphBuilder;
use super::csr::{CsrGraph, VertexId};

/// Largest vertex id an edge list may name. The loader allocates dense
/// id space up to the maximum id it sees, so one corrupt token (a
/// timestamp column, a hash, a stray weight) would otherwise turn into
/// a multi-gigabyte allocation; 2^28 vertices is far above every
/// dataset this repo handles.
pub const MAX_EDGE_LIST_VERTEX: VertexId = (1 << 28) - 1;

/// Load a whitespace-separated edge list: `u v` per line, `#` comments.
/// Vertex ids are assigned densely from the raw ids encountered; ids
/// above [`MAX_EDGE_LIST_VERTEX`] are rejected with a named
/// `InvalidData` error instead of driving an absurd allocation.
pub fn load_edge_list(path: &Path) -> std::io::Result<CsrGraph> {
    let f = std::fs::File::open(path)?;
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut max_v: VertexId = 0;
    for line in BufReader::new(f).lines() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let (u, v) = (
            parse_id(it.next(), path)?,
            parse_id(it.next(), path)?,
        );
        let hi = u.max(v);
        if hi > MAX_EDGE_LIST_VERTEX {
            return Err(bad_data(format!(
                "{path:?}: vertex id {hi} exceeds the edge-list limit \
                 {MAX_EDGE_LIST_VERTEX} (ids are allocated densely — is this \
                 column really a vertex id?)"
            )));
        }
        max_v = max_v.max(hi);
        edges.push((u, v));
    }
    Ok(GraphBuilder::from_edges(max_v as usize + 1, &edges).build())
}

/// Load a labeled graph: lines `v <label>` in a `# labels` section follow
/// the edge lines, or a companion `<path>.labels` file with one label per
/// vertex line.
pub fn load_labels(path: &Path, n: usize) -> std::io::Result<Vec<u32>> {
    let f = std::fs::File::open(path)?;
    let mut labels = vec![0u32; n];
    for (i, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || i >= n {
            continue;
        }
        labels[i] = line.parse().map_err(bad_data)?;
    }
    Ok(labels)
}

fn parse_id(tok: Option<&str>, path: &Path) -> std::io::Result<VertexId> {
    tok.ok_or_else(|| bad_data(format!("{path:?}: missing vertex id")))?
        .parse()
        .map_err(bad_data)
}

fn bad_data<E: std::fmt::Display>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

/// Save an edge list (undirected edges once, u < v).
pub fn save_edge_list(g: &CsrGraph, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

const SNAPSHOT_MAGIC: u64 = 0x53_41_4E_44_43_53_52_31; // "SANDCSR1"

/// Binary snapshot: magic, n, m, has_labels, offsets, neighbors, labels.
pub fn save_snapshot(g: &CsrGraph, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    let n = g.num_vertices() as u64;
    let m = g.neighbors.len() as u64;
    let has_labels = g.is_labeled() as u64;
    for x in [SNAPSHOT_MAGIC, n, m, has_labels] {
        w.write_all(&x.to_le_bytes())?;
    }
    for &o in &g.offsets {
        w.write_all(&o.to_le_bytes())?;
    }
    for &v in &g.neighbors {
        w.write_all(&v.to_le_bytes())?;
    }
    for &l in &g.labels {
        w.write_all(&l.to_le_bytes())?;
    }
    Ok(())
}

/// Exact byte length a snapshot with this header must have: 4 header
/// words + (n+1) u64 offsets + m u32 neighbors + n u32 labels.
/// `None` when the header sizes overflow — such a header is corrupt by
/// construction.
fn snapshot_byte_len(n: u64, m: u64, has_labels: bool) -> Option<u64> {
    let mut total = 32u64; // magic, n, m, has_labels
    total = total.checked_add(n.checked_add(1)?.checked_mul(8)?)?;
    total = total.checked_add(m.checked_mul(4)?)?;
    if has_labels {
        total = total.checked_add(n.checked_mul(4)?)?;
    }
    Some(total)
}

/// Load a binary CSR snapshot produced by the save path.
///
/// The header is validated against the file length *before* any
/// allocation (a corrupt `n`/`m` must not drive `Vec::with_capacity`),
/// and the decoded arrays are checked against the CSR invariants —
/// `offsets[0] == 0`, offsets monotone, `offsets[n] == m`, every
/// neighbor `< n` — so a truncated or bit-flipped snapshot fails here
/// with a named error instead of panicking deep inside an engine.
pub fn load_snapshot(path: &Path) -> std::io::Result<CsrGraph> {
    let file_len = std::fs::metadata(path)?.len();
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let magic = read_u64(&mut r)?;
    if magic != SNAPSHOT_MAGIC {
        return Err(bad_data("not a sandslash CSR snapshot"));
    }
    let n64 = read_u64(&mut r)?;
    let m64 = read_u64(&mut r)?;
    let has_labels = read_u64(&mut r)? != 0;
    match snapshot_byte_len(n64, m64, has_labels) {
        Some(expect) if expect == file_len => {}
        Some(expect) => {
            return Err(bad_data(format!(
                "{path:?}: snapshot header (n={n64}, m={m64}, labels={has_labels}) \
                 implies {expect} bytes but the file holds {file_len} — truncated \
                 or corrupt snapshot"
            )));
        }
        None => {
            return Err(bad_data(format!(
                "{path:?}: snapshot header sizes overflow (n={n64}, m={m64})"
            )));
        }
    }
    let (n, m) = (n64 as usize, m64 as usize);
    let mut offsets = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        offsets.push(read_u64(&mut r)?);
    }
    let mut neighbors = Vec::with_capacity(m);
    for _ in 0..m {
        neighbors.push(read_u32(&mut r)?);
    }
    let mut labels = Vec::new();
    if has_labels {
        labels.reserve(n);
        for _ in 0..n {
            labels.push(read_u32(&mut r)?);
        }
    }
    // CSR invariants
    if offsets[0] != 0 {
        return Err(bad_data(format!(
            "{path:?}: corrupt snapshot: offsets[0] = {} (must be 0)",
            offsets[0]
        )));
    }
    if let Some(v) = offsets.windows(2).position(|w| w[0] > w[1]) {
        return Err(bad_data(format!(
            "{path:?}: corrupt snapshot: offsets not monotone at vertex {v} \
             ({} > {})",
            offsets[v],
            offsets[v + 1]
        )));
    }
    if offsets[n] != m64 {
        return Err(bad_data(format!(
            "{path:?}: corrupt snapshot: offsets[{n}] = {} but the header \
             declares m = {m64}",
            offsets[n]
        )));
    }
    if let Some(i) = neighbors.iter().position(|&v| v as u64 >= n64) {
        return Err(bad_data(format!(
            "{path:?}: corrupt snapshot: neighbors[{i}] = {} out of range \
             (n = {n64})",
            neighbors[i]
        )));
    }
    Ok(CsrGraph { offsets, neighbors, labels })
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("sandslash_io_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = gen::erdos_renyi(50, 0.2, 7, &[]);
        let path = tmp("el.txt");
        save_edge_list(&g, &path).unwrap();
        let h = load_edge_list(&path).unwrap();
        assert_eq!(g.num_undirected_edges(), h.num_undirected_edges());
        for (u, v) in g.edges() {
            assert!(h.has_edge(u, v));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn snapshot_roundtrip_labeled() {
        let g = gen::erdos_renyi(40, 0.15, 9, &[0, 1, 2]);
        let path = tmp("snap.bin");
        save_snapshot(&g, &path).unwrap();
        let h = load_snapshot(&path).unwrap();
        assert_eq!(g.offsets, h.offsets);
        assert_eq!(g.neighbors, h.neighbors);
        assert_eq!(g.labels, h.labels);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad.bin");
        std::fs::write(&path, [0u8; 64]).unwrap();
        assert!(load_snapshot(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated_snapshot() {
        let g = gen::erdos_renyi(30, 0.2, 11, &[]);
        let path = tmp("trunc.bin");
        save_snapshot(&g, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 5);
        std::fs::write(&path, &bytes).unwrap();
        let err = load_snapshot(&path).expect_err("truncated snapshot must fail");
        assert!(err.to_string().contains("truncated or corrupt"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_corrupt_offsets() {
        let g = gen::erdos_renyi(30, 0.2, 12, &[]);
        let path = tmp("badoff.bin");
        save_snapshot(&g, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // offsets[1] lives at byte 40; make it huge so monotonicity (or
        // the offsets[n] == m check) trips while the length stays right
        bytes[40..48].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_snapshot(&path).expect_err("corrupt offsets must fail");
        assert!(err.to_string().contains("corrupt snapshot"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_out_of_range_neighbor() {
        let g = gen::ring(8);
        let path = tmp("badnbr.bin");
        save_snapshot(&g, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // first neighbor word sits right after the header + 9 offsets
        let pos = 32 + 9 * 8;
        bytes[pos..pos + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_snapshot(&path).expect_err("out-of-range neighbor must fail");
        assert!(err.to_string().contains("out of range"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_absurd_edge_list_ids() {
        let path = tmp("absurd.el");
        std::fs::write(&path, "0 1\n2 999999999\n").unwrap();
        let err = load_edge_list(&path).expect_err("absurd vertex id must fail");
        assert!(err.to_string().contains("edge-list limit"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn edge_list_with_comments() {
        let path = tmp("comments.el");
        std::fs::write(&path, "# header\n0 1\n1 2 # trailing\n\n2 0\n").unwrap();
        let g = load_edge_list(&path).unwrap();
        assert_eq!(g.num_undirected_edges(), 3);
        std::fs::remove_file(path).ok();
    }
}
