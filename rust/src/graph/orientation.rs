//! Orientation (total-order DAG construction) and k-core decomposition —
//! paper Appendix B.2.
//!
//! For clique patterns, Sandslash converts the symmetric input graph into
//! a DAG so each clique is enumerated exactly once with no runtime
//! symmetry checks. Two schemes, as in the paper: (1) degree-based (each
//! edge points to the higher-degree endpoint, ties to larger id), and
//! (2) core-based (degeneracy order, as in kClist) which bounds the
//! out-degree by the graph's degeneracy — the key to kClist-style local
//! graphs staying small.

use super::csr::{CsrGraph, VertexId};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Total-order scheme used to orient the graph.
pub enum OrientScheme {
    /// Rank by (degree, id): each edge points to the higher endpoint.
    Degree,
    /// Degeneracy (peel) order, as in kClist.
    Core,
}

/// Directed adjacency produced by orientation: `out[v]` is sorted by the
/// *rank* order used for orientation, stored as original vertex ids
/// sorted ascending (sorted lists keep intersections cheap).
#[derive(Clone, Debug)]
pub struct Dag {
    /// Offsets into `targets`; length n + 1.
    pub offsets: Vec<u64>,
    /// Concatenated sorted out-neighbor lists.
    pub targets: Vec<VertexId>,
    /// rank[v] = position of v in the total order (smaller = earlier).
    pub rank: Vec<u32>,
}

impl Dag {
    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    /// Sorted out-neighbors of `v` (higher-ranked endpoints).
    pub fn out_neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.targets[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
    }

    #[inline]
    /// Out-degree of `v`.
    pub fn out_degree(&self, v: VertexId) -> usize {
        self.out_neighbors(v).len()
    }

    /// Largest out-degree (bounded by the degeneracy under `Core`).
    pub fn max_out_degree(&self) -> usize {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.out_degree(v))
            .max()
            .unwrap_or(0)
    }
}

/// Build a DAG under the given scheme.
pub fn orient(g: &CsrGraph, scheme: OrientScheme) -> Dag {
    let rank: Vec<u32> = match scheme {
        OrientScheme::Degree => {
            // rank by (degree, id): edge points to higher (degree, id)
            let n = g.num_vertices();
            let mut order: Vec<VertexId> = (0..n as VertexId).collect();
            order.sort_by_key(|&v| (g.degree(v), v));
            let mut rank = vec![0u32; n];
            for (r, &v) in order.iter().enumerate() {
                rank[v as usize] = r as u32;
            }
            rank
        }
        OrientScheme::Core => degeneracy_order(g).1,
    };
    build_dag(g, &rank)
}

fn build_dag(g: &CsrGraph, rank: &[u32]) -> Dag {
    let n = g.num_vertices();
    let mut offsets = vec![0u64; n + 1];
    for v in 0..n as VertexId {
        let d = g
            .neighbors(v)
            .iter()
            .filter(|&&u| rank[u as usize] > rank[v as usize])
            .count();
        offsets[v as usize + 1] = d as u64;
    }
    for i in 0..n {
        offsets[i + 1] += offsets[i];
    }
    let mut targets = vec![0 as VertexId; offsets[n] as usize];
    let mut cursor: Vec<u64> = offsets.clone();
    for v in 0..n as VertexId {
        for &u in g.neighbors(v) {
            if rank[u as usize] > rank[v as usize] {
                targets[cursor[v as usize] as usize] = u;
                cursor[v as usize] += 1;
            }
        }
        // neighbors(v) is sorted by id; keep out-lists sorted by id too
        let s = offsets[v as usize] as usize;
        let e = cursor[v as usize] as usize;
        targets[s..e].sort_unstable();
    }
    Dag { offsets, targets, rank: rank.to_vec() }
}

/// Peeling k-core decomposition (Matula–Beck). Returns (core numbers,
/// degeneracy rank) where rank follows the peel order.
pub fn degeneracy_order(g: &CsrGraph) -> (Vec<u32>, Vec<u32>) {
    let n = g.num_vertices();
    let max_d = g.max_degree();
    let mut deg: Vec<u32> = (0..n as VertexId).map(|v| g.degree(v) as u32).collect();
    // bucket sort by degree
    let mut bins = vec![0usize; max_d + 2];
    for &d in &deg {
        bins[d as usize + 1] += 1;
    }
    for i in 1..bins.len() {
        bins[i] += bins[i - 1];
    }
    let mut pos = vec![0usize; n];
    let mut order = vec![0 as VertexId; n];
    {
        let mut cursor = bins.clone();
        for v in 0..n as VertexId {
            let d = deg[v as usize] as usize;
            pos[v as usize] = cursor[d];
            order[cursor[d]] = v;
            cursor[d] += 1;
        }
    }
    let mut bin_start = bins;
    let mut core = vec![0u32; n];
    let mut rank = vec![0u32; n];
    let mut current_core = 0u32;
    for i in 0..n {
        let v = order[i];
        current_core = current_core.max(deg[v as usize]);
        core[v as usize] = current_core;
        rank[v as usize] = i as u32;
        for &u in g.neighbors(v) {
            let du = deg[u as usize];
            if du > deg[v as usize] && pos[u as usize] > i {
                // move u one bucket down: swap with first element of its bucket
                let bucket = du as usize;
                let first_pos = bin_start[bucket].max(i + 1);
                let w = order[first_pos];
                if w != u {
                    let pu = pos[u as usize];
                    order.swap(first_pos, pu);
                    pos[u as usize] = first_pos;
                    pos[w as usize] = pu;
                }
                bin_start[bucket] = first_pos + 1;
                deg[u as usize] -= 1;
            }
        }
    }
    (core, rank)
}

/// Graph degeneracy = max core number.
pub fn degeneracy(g: &CsrGraph) -> u32 {
    degeneracy_order(g).0.into_iter().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn dag_halves_edges() {
        let g = gen::rmat(8, 8, 11, &[]);
        for scheme in [OrientScheme::Degree, OrientScheme::Core] {
            let d = orient(&g, scheme);
            assert_eq!(d.targets.len(), g.num_undirected_edges());
        }
    }

    #[test]
    fn dag_is_acyclic_by_rank() {
        let g = gen::rmat(7, 6, 3, &[]);
        let d = orient(&g, OrientScheme::Degree);
        for v in 0..g.num_vertices() as VertexId {
            for &u in d.out_neighbors(v) {
                assert!(d.rank[u as usize] > d.rank[v as usize]);
            }
        }
    }

    #[test]
    fn out_lists_sorted() {
        let g = gen::rmat(7, 6, 4, &[]);
        let d = orient(&g, OrientScheme::Core);
        for v in 0..g.num_vertices() as VertexId {
            assert!(d.out_neighbors(v).windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn complete_graph_core_numbers() {
        let g = gen::complete(6);
        let (core, _) = degeneracy_order(&g);
        assert!(core.iter().all(|&c| c == 5));
        assert_eq!(degeneracy(&g), 5);
    }

    #[test]
    fn ring_core_is_two() {
        let g = gen::ring(12);
        assert_eq!(degeneracy(&g), 2);
    }

    #[test]
    fn core_orientation_bounds_outdegree_by_degeneracy() {
        let g = gen::rmat(9, 8, 5, &[]);
        let d = orient(&g, OrientScheme::Core);
        let k = degeneracy(&g) as usize;
        assert!(
            d.max_out_degree() <= k,
            "max_out={} degeneracy={}",
            d.max_out_degree(),
            k
        );
    }

    #[test]
    fn star_core_is_one() {
        let mut b = crate::graph::builder::GraphBuilder::new(6);
        for v in 1..6 {
            b.add_edge(0, v);
        }
        let g = b.build();
        assert_eq!(degeneracy(&g), 1);
    }
}
