//! Graph construction: edge list -> clean symmetric CSR.
//!
//! Mirrors the preprocessing the paper applies to its inputs (Table 4):
//! symmetrize, drop self loops, dedupe, sort neighbor lists.

use super::csr::{CsrGraph, VertexId};

/// Accumulates raw (possibly dirty) edges, then builds a clean CSR.
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
    labels: Vec<u32>,
}

impl GraphBuilder {
    /// Builder for a graph on `n` vertices.
    pub fn new(n: usize) -> Self {
        Self { n, edges: Vec::new(), labels: Vec::new() }
    }

    /// Builder pre-loaded with `edges`.
    pub fn from_edges(n: usize, edges: &[(VertexId, VertexId)]) -> Self {
        let mut b = Self::new(n);
        for &(u, v) in edges {
            b.add_edge(u, v);
        }
        b
    }

    /// Record an undirected edge (loops/dupes cleaned at build).
    pub fn add_edge(&mut self, u: VertexId, v: VertexId) {
        self.edges.push((u, v));
    }

    /// Attach one label per vertex.
    pub fn with_labels(mut self, labels: Vec<u32>) -> Self {
        assert_eq!(labels.len(), self.n);
        self.labels = labels;
        self
    }

    /// Finalize: symmetrize, drop loops, dedupe, sort adjacency.
    pub fn build(self) -> CsrGraph {
        let n = self.n;
        let mut deg = vec![0u64; n];
        let mut dir: Vec<(VertexId, VertexId)> =
            Vec::with_capacity(self.edges.len() * 2);
        for &(u, v) in &self.edges {
            if u == v {
                continue; // no self loops
            }
            assert!((u as usize) < n && (v as usize) < n, "edge out of range");
            dir.push((u, v));
            dir.push((v, u));
        }
        dir.sort_unstable();
        dir.dedup();
        for &(u, _) in &dir {
            deg[u as usize] += 1;
        }
        let mut offsets = vec![0u64; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut neighbors = vec![0 as VertexId; dir.len()];
        let mut cursor = offsets.clone();
        for &(u, v) in &dir {
            neighbors[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
        }
        CsrGraph { offsets, neighbors, labels: self.labels }
    }
}

/// Relabel a graph's vertices by `perm` (new_id = perm[old_id]),
/// preserving labels. Used by tests to check relabeling invariance and by
/// the degree-sorted dense-tile path.
pub fn relabel(g: &CsrGraph, perm: &[VertexId]) -> CsrGraph {
    let n = g.num_vertices();
    assert_eq!(perm.len(), n);
    let mut b = GraphBuilder::new(n);
    for (u, v) in g.edges() {
        b.add_edge(perm[u as usize], perm[v as usize]);
    }
    if g.is_labeled() {
        let mut labels = vec![0u32; n];
        for old in 0..n {
            labels[perm[old] as usize] = g.labels[old];
        }
        b = b.with_labels(labels);
    }
    b.build()
}

/// Permutation that sorts vertices by descending degree (ties by id).
pub fn degree_desc_order(g: &CsrGraph) -> Vec<VertexId> {
    let n = g.num_vertices();
    let mut order: Vec<VertexId> = (0..n as VertexId).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(g.degree(v)), v));
    // order[rank] = old vertex; invert into perm[old] = rank
    let mut perm = vec![0 as VertexId; n];
    for (rank, &old) in order.iter().enumerate() {
        perm[old as usize] = rank as VertexId;
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedupes_and_symmetrizes() {
        let g = GraphBuilder::from_edges(3, &[(0, 1), (1, 0), (0, 1), (1, 2)]).build();
        assert_eq!(g.num_undirected_edges(), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn drops_self_loops() {
        let g = GraphBuilder::from_edges(2, &[(0, 0), (0, 1)]).build();
        assert_eq!(g.num_undirected_edges(), 1);
        assert_eq!(g.neighbors(0), &[1]);
    }

    #[test]
    fn relabel_preserves_structure() {
        let g = GraphBuilder::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]).build();
        let perm = vec![3, 2, 1, 0];
        let h = relabel(&g, &perm);
        assert_eq!(h.num_undirected_edges(), 4);
        assert!(h.has_edge(3, 2)); // old (0,1)
        assert!(h.has_edge(0, 3)); // old (3,0)
        assert_eq!(h.degree(0), 2);
    }

    #[test]
    fn relabel_moves_labels() {
        let g = GraphBuilder::from_edges(3, &[(0, 1), (1, 2)])
            .with_labels(vec![10, 20, 30])
            .build();
        let h = relabel(&g, &[2, 1, 0]);
        assert_eq!(h.label(2), 10);
        assert_eq!(h.label(0), 30);
    }

    #[test]
    fn degree_order_sorts_desc() {
        let g = GraphBuilder::from_edges(4, &[(0, 1), (0, 2), (0, 3), (1, 2)]).build();
        let perm = degree_desc_order(&g);
        assert_eq!(perm[0], 0); // vertex 0 has max degree -> rank 0
        let h = relabel(&g, &perm);
        let degs: Vec<usize> = (0..4).map(|v| h.degree(v)).collect();
        assert!(degs.windows(2).all(|w| w[0] >= w[1]));
    }
}
