//! Graph statistics reporting (the `sandslash stats` subcommand) —
//! reproduces the columns of the paper's Table 4 for our inputs.

use super::csr::CsrGraph;
use super::orientation;

#[derive(Debug, Clone)]
/// The Table-4 statistics columns for one input graph.
pub struct GraphStats {
    /// Number of vertices.
    pub vertices: usize,
    /// Number of undirected edges.
    pub edges: usize,
    /// Average degree (2|E| / |V|).
    pub avg_degree: f64,
    /// Maximum degree.
    pub max_degree: usize,
    /// Graph degeneracy (maximum core number).
    pub degeneracy: u32,
    /// Number of distinct vertex labels (0 = unlabeled).
    pub labels: usize,
}

/// Compute the statistics of `g`.
pub fn stats(g: &CsrGraph) -> GraphStats {
    GraphStats {
        vertices: g.num_vertices(),
        edges: g.num_undirected_edges(),
        avg_degree: g.num_directed_edges() as f64 / g.num_vertices().max(1) as f64,
        max_degree: g.max_degree(),
        degeneracy: orientation::degeneracy(g),
        labels: g.num_labels(),
    }
}

impl std::fmt::Display for GraphStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "|V|={} |E|={} avg_deg={:.1} max_deg={} degeneracy={} labels={}",
            self.vertices, self.edges, self.avg_degree, self.max_degree,
            self.degeneracy, self.labels
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn stats_of_complete_graph() {
        let s = stats(&gen::complete(5));
        assert_eq!(s.vertices, 5);
        assert_eq!(s.edges, 10);
        assert_eq!(s.max_degree, 4);
        assert_eq!(s.degeneracy, 4);
        assert_eq!(s.labels, 0);
    }

    #[test]
    fn display_contains_fields() {
        let s = stats(&gen::ring(8)).to_string();
        assert!(s.contains("|V|=8") && s.contains("degeneracy=2"));
    }
}
