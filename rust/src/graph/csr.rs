//! Compressed Sparse Row graph, the input representation for all mining.
//!
//! Matches the paper's setup (Table 4): symmetric, no self loops, no
//! duplicate edges, neighbor lists sorted ascending. Sorted adjacency is
//! what makes intersection-based connectivity checks and symmetry
//! breaking cheap. Optional vertex labels support FSM.

/// Vertex identifier (`u32` keeps CSR arrays compact).
pub type VertexId = u32;

#[derive(Clone, Debug, Default)]
/// Symmetric CSR graph; see the module docs for the invariants.
pub struct CsrGraph {
    /// Offsets into `neighbors`; length = n + 1.
    pub offsets: Vec<u64>,
    /// Concatenated sorted neighbor lists.
    pub neighbors: Vec<VertexId>,
    /// Optional vertex labels (empty = unlabeled graph).
    pub labels: Vec<u32>,
}

impl CsrGraph {
    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Number of directed edges stored (for symmetric graphs this is 2x
    /// the undirected edge count).
    pub fn num_directed_edges(&self) -> usize {
        self.neighbors.len()
    }

    /// Number of undirected edges.
    pub fn num_undirected_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    #[inline]
    /// Degree of `v`.
    pub fn degree(&self, v: VertexId) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    #[inline]
    /// Sorted neighbor list of `v`.
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.neighbors[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
    }

    #[inline]
    /// Label of `v` (0 for unlabeled graphs).
    pub fn label(&self, v: VertexId) -> u32 {
        if self.labels.is_empty() { 0 } else { self.labels[v as usize] }
    }

    /// Whether vertex labels are present.
    pub fn is_labeled(&self) -> bool {
        !self.labels.is_empty()
    }

    /// One past the largest label value (0 when unlabeled).
    pub fn num_labels(&self) -> usize {
        self.labels.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0)
    }

    /// Edge test via binary search on the sorted neighbor list of the
    /// lower-degree endpoint.
    #[inline]
    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        let (a, b) = if self.degree(u) <= self.degree(v) { (u, v) } else { (v, u) };
        self.neighbors(a).binary_search(&b).is_ok()
    }

    /// Iterate undirected edges (u < v) in CSR order.
    pub fn edges(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_vertices() as VertexId).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .copied()
                .filter(move |&v| u < v)
                .map(move |v| (u, v))
        })
    }

    /// Largest vertex degree.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Sorted-list intersection count (adaptive kernel).
    pub fn intersect_count(&self, u: VertexId, v: VertexId) -> usize {
        intersect_count(self.neighbors(u), self.neighbors(v))
    }

    /// Sorted-list intersection into `out` (cleared first).
    pub fn intersect_into(&self, u: VertexId, v: VertexId, out: &mut Vec<VertexId>) {
        out.clear();
        intersect_into(self.neighbors(u), self.neighbors(v), out);
    }
}

// The tuned set kernels live in `graph::setops` (adaptive merge /
// gallop / bitset selection — crossovers in EXPERIMENTS.md); re-exported
// here because the neighbor-list slices they operate on are CSR rows.
pub use super::setops::{count_less_than, intersect_count, intersect_into};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn diamond() -> CsrGraph {
        // 0-1, 0-2, 1-2, 1-3, 2-3 (diamond = 4-clique minus edge 0-3)
        GraphBuilder::from_edges(4, &[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)]).build()
    }

    #[test]
    fn degrees_and_neighbors_sorted() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_undirected_edges(), 5);
        assert_eq!(g.degree(1), 3);
        assert_eq!(g.neighbors(1), &[0, 2, 3]);
        assert!(g.neighbors(2).windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn has_edge_both_directions() {
        let g = diamond();
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(!g.has_edge(0, 3));
    }

    #[test]
    fn edges_iterator_unique_ordered() {
        let g = diamond();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)]);
    }

    #[test]
    fn intersections() {
        let g = diamond();
        assert_eq!(g.intersect_count(1, 2), 2); // common: 0 and 3
        let mut out = Vec::new();
        g.intersect_into(1, 2, &mut out);
        assert_eq!(out, vec![0, 3]);
    }

    #[test]
    fn reexported_kernels_visible_through_csr() {
        // kernel-level tests live in graph::setops; this guards the
        // re-export surface existing callers rely on
        let a: Vec<u32> = (0..1000).step_by(7).collect();
        let b: Vec<u32> = vec![14, 21, 500, 700, 999];
        assert_eq!(intersect_count(&b, &a), 3); // 14, 21, 700
        assert_eq!(count_less_than(&b, 500), 2);
    }
}
