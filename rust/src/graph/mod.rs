//! Graph substrate: CSR storage, construction, IO, synthetic generators,
//! degeneracy/orientation preprocessing and statistics.

pub mod builder;
pub mod csr;
pub mod gen;
pub mod io;
pub mod orientation;
pub mod stats;

pub use csr::{CsrGraph, VertexId};
