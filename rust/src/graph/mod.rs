//! Graph substrate: CSR storage, construction, IO, synthetic generators,
//! degeneracy/orientation preprocessing, statistics, and the adaptive
//! set-operation kernels ([`setops`]) every extension path runs on.

pub mod builder;
pub mod csr;
pub mod gen;
pub mod io;
pub mod orientation;
pub mod setops;
pub mod stats;

pub use csr::{CsrGraph, VertexId};
