//! Set-operation kernels — the single tuned implementation of sorted-set
//! intersection / difference in the system.
//!
//! Sandslash's performance hinges on fast subgraph extension (paper
//! §4–§5): MNC and LG exist precisely to replace per-candidate edge
//! probes with set operations, and every fast path (TC, k-CL, SL, the
//! set-centric DFS frontier) bottoms out here. Four kernel families,
//! chosen adaptively by length/density heuristics (crossovers recorded
//! in EXPERIMENTS.md):
//!
//! * **linear merge** — both lists walked in lockstep; best when the
//!   lengths are within ~[`GALLOP_FACTOR`] of each other and at least
//!   one side is too short for a vector block.
//! * **galloping** — each element of the short list binary-searched in a
//!   shrinking window of the long list; wins when the lengths are skewed
//!   by more than [`GALLOP_FACTOR`].
//! * **SIMD block merge** — `std::arch` x86_64 shuffle kernels
//!   (SSE/SSSE3 4-lane, AVX2 8-lane): compare one block of each list
//!   all-pairs via lane rotations, advance the block with the smaller
//!   maximum, compact matches with a shuffle LUT. Selected when both
//!   operands have at least [`SIMD_MIN_LEN`] elements and the CPU
//!   reports the feature at runtime (`is_x86_feature_detected!`); the
//!   portable scalar kernels remain the fallback and the differential
//!   oracle. `SANDSLASH_NO_SIMD=1` (or
//!   [`set_simd_enabled`]`(false)`) forces the scalar path.
//! * **word-parallel / bitset** — O(1) word-indexed membership probes
//!   against a pre-built neighborhood bitmap ([`BitSet`]), and
//!   bitset×bitset AND(+popcount) over raw words — 64 memberships per
//!   instruction pair — for dense frontiers, embedding-adjacency mask
//!   scans, and gathered connectivity-code filters.
//!
//! Every dispatch decision increments a process-global counter in
//! [`crate::util::metrics::dispatch`], so tests and benches can assert
//! which family actually ran.
//!
//! Bounded variants (`*_below`) fuse a symmetry-breaking upper bound
//! into the kernel so candidates violating `cand < bound` are never
//! even visited — the DFS frontier achieves the same fusion by slicing
//! its seed list, these are for callers intersecting directly;
//! [`difference_into`] is the anti-intersection needed by
//! vertex-induced (non-adjacency) constraints.
//!
//! All kernels operate on sorted, duplicate-free slices (CSR neighbor
//! rows are maintained that way by construction):
//!
//! ```
//! use sandslash::graph::setops;
//!
//! let a: Vec<u32> = vec![1, 3, 5, 7];
//! let b: Vec<u32> = vec![3, 4, 5, 9];
//! assert_eq!(setops::intersect_count(&a, &b), 2);
//!
//! let mut out = Vec::new();
//! setops::intersect_into(&a, &b, &mut out);
//! assert_eq!(out, vec![3, 5]);
//!
//! // symmetry-breaking bound fused: elements >= 5 are never visited
//! assert_eq!(setops::intersect_count_below(&a, &b, 5), 1);
//!
//! // anti-intersection for vertex-induced (non-edge) constraints
//! out.clear();
//! setops::difference_into(&a, &b, &mut out);
//! assert_eq!(out, vec![1, 7]);
//!
//! // the vectorized and scalar kernels are interchangeable
//! setops::set_simd_enabled(false);
//! assert_eq!(setops::intersect_count(&a, &b), 2);
//! setops::set_simd_enabled(true); // back to runtime detection
//! ```

use super::csr::VertexId;
use crate::util::bitset::BitSet;
use crate::util::metrics::dispatch;
use std::sync::atomic::{AtomicU8, Ordering};

/// Length-skew crossover between linear merge and galloping: gallop when
/// `short * GALLOP_FACTOR < long`. The merge costs O(short + long), the
/// gallop O(short * log(long)); 32 puts the switch safely past the point
/// where the binary-search branch misses stop paying for themselves
/// (measured in the §Perf pass, see EXPERIMENTS.md).
pub const GALLOP_FACTOR: usize = 32;

/// Minimum operand length for the vectorized block merge: below one
/// AVX2 block per side the setup and scalar tail dominate, so shorter
/// inputs stay on the scalar merge (EXPERIMENTS.md §PR-3).
pub const SIMD_MIN_LEN: usize = 8;

#[inline]
fn skewed(short: usize, long: usize) -> bool {
    short * GALLOP_FACTOR < long
}

// ---------------------------------------------------------------------------
// Runtime SIMD mode (cached CPU feature detection + kill switches)
// ---------------------------------------------------------------------------

/// Cached SIMD mode byte: 0 = undetected; low nibble = merge-kernel
/// level (1 scalar / 2 ssse3 / 3 avx2); bit 4 = POPCNT available.
static SIMD_MODE: AtomicU8 = AtomicU8::new(0);

const MODE_SCALAR: u8 = 1;
const MODE_SSE: u8 = 2;
const MODE_AVX2: u8 = 3;
const MODE_LEVEL_MASK: u8 = 0x0F;
const MODE_POPCNT: u8 = 0x10;

/// Vectorization level selected for the merge kernels (cached runtime
/// CPU detection; see [`set_simd_enabled`] for the overrides).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar kernels only (non-x86_64, old CPUs, or forced).
    Scalar,
    /// SSE/SSSE3 4-lane shuffle kernels.
    Sse,
    /// AVX2 8-lane shuffle/permute kernels (plus gathered filters).
    Avx2,
}

#[inline]
fn simd_mode() -> u8 {
    match SIMD_MODE.load(Ordering::Relaxed) {
        0 => detect_simd_mode(),
        m => m,
    }
}

#[cold]
fn detect_simd_mode() -> u8 {
    let m = compute_simd_mode();
    SIMD_MODE.store(m, Ordering::Relaxed);
    m
}

#[cfg(target_arch = "x86_64")]
fn compute_simd_mode() -> u8 {
    // Miri interprets MIR and has no CPUID or `std::arch` vector
    // intrinsics; pin the interpreter to the portable scalar kernels so
    // `cargo miri test` exercises the pointer arithmetic it *can* check
    // (the scalar merges, the bitset words) instead of aborting on an
    // unsupported intrinsic.
    if cfg!(miri) {
        return MODE_SCALAR;
    }
    let disabled = std::env::var("SANDSLASH_NO_SIMD")
        .is_ok_and(|v| !v.trim().is_empty() && v.trim() != "0");
    if disabled {
        return MODE_SCALAR;
    }
    let mut m = if is_x86_feature_detected!("avx2") {
        MODE_AVX2
    } else if is_x86_feature_detected!("ssse3") {
        MODE_SSE
    } else {
        MODE_SCALAR
    };
    if is_x86_feature_detected!("popcnt") {
        m |= MODE_POPCNT;
    }
    m
}

#[cfg(not(target_arch = "x86_64"))]
fn compute_simd_mode() -> u8 {
    MODE_SCALAR
}

/// The merge-kernel vectorization level currently in effect.
pub fn simd_level() -> SimdLevel {
    match simd_mode() & MODE_LEVEL_MASK {
        MODE_AVX2 => SimdLevel::Avx2,
        MODE_SSE => SimdLevel::Sse,
        _ => SimdLevel::Scalar,
    }
}

/// Whether any vectorized merge kernel is active (false on non-x86
/// builds, pre-SSSE3 CPUs, under `SANDSLASH_NO_SIMD=1`, or after
/// [`set_simd_enabled`]`(false)`).
pub fn simd_active() -> bool {
    simd_level() != SimdLevel::Scalar
}

/// Human-readable dispatch level for bench metadata rows.
pub fn simd_level_name() -> &'static str {
    match simd_level() {
        SimdLevel::Avx2 => "avx2",
        SimdLevel::Sse => "ssse3",
        SimdLevel::Scalar => "scalar",
    }
}

/// Force the portable scalar kernels (`false`) or return to runtime
/// feature detection (`true`, which still honors `SANDSLASH_NO_SIMD`).
///
/// Process-global, for benches and differential tests that need
/// scalar-vs-SIMD rows *from the same run*; every kernel is correct at
/// every level, so flipping this concurrently never changes results —
/// only which counters in [`crate::util::metrics::dispatch`] move.
pub fn set_simd_enabled(on: bool) {
    let m = if on { 0 } else { MODE_SCALAR };
    SIMD_MODE.store(m, Ordering::Relaxed);
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn popcnt_enabled() -> bool {
    simd_mode() & MODE_POPCNT != 0
}

// ---------------------------------------------------------------------------
// Adaptive entry points
// ---------------------------------------------------------------------------

/// |a ∩ b| for sorted slices; adaptive merge/gallop/SIMD.
#[inline]
pub fn intersect_count(a: &[VertexId], b: &[VertexId]) -> usize {
    if skewed(a.len(), b.len()) {
        dispatch::note_gallop();
        return gallop_count(a, b);
    }
    if skewed(b.len(), a.len()) {
        dispatch::note_gallop();
        return gallop_count(b, a);
    }
    #[cfg(target_arch = "x86_64")]
    if a.len() >= SIMD_MIN_LEN && b.len() >= SIMD_MIN_LEN {
        match simd_level() {
            SimdLevel::Avx2 => {
                dispatch::note_simd_merge();
                // SAFETY: AVX2 support verified by runtime detection.
                return unsafe { x86::intersect_count_avx2(a, b) };
            }
            SimdLevel::Sse => {
                dispatch::note_simd_merge();
                // SAFETY: SSSE3 support verified by runtime detection.
                return unsafe { x86::intersect_count_sse(a, b) };
            }
            SimdLevel::Scalar => {}
        }
    }
    dispatch::note_merge();
    merge_count(a, b)
}

/// a ∩ b appended to `out` (not cleared); adaptive merge/gallop/SIMD.
#[inline]
pub fn intersect_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    if skewed(a.len(), b.len()) {
        dispatch::note_gallop();
        return gallop_into(a, b, out);
    }
    if skewed(b.len(), a.len()) {
        dispatch::note_gallop();
        return gallop_into(b, a, out);
    }
    #[cfg(target_arch = "x86_64")]
    if a.len() >= SIMD_MIN_LEN && b.len() >= SIMD_MIN_LEN {
        match simd_level() {
            SimdLevel::Avx2 => {
                dispatch::note_simd_merge();
                // SAFETY: AVX2 support verified by runtime detection.
                return unsafe { x86::intersect_into_avx2(a, b, out) };
            }
            SimdLevel::Sse => {
                dispatch::note_simd_merge();
                // SAFETY: SSSE3 support verified by runtime detection.
                return unsafe { x86::intersect_into_sse(a, b, out) };
            }
            SimdLevel::Scalar => {}
        }
    }
    dispatch::note_merge();
    merge_into(a, b, out)
}

/// |{x ∈ a ∩ b : x < bound}| with the bound fused into the kernel: both
/// inputs are pre-truncated by binary search, so elements ≥ bound are
/// never visited (symmetry-breaking `lt` constraints).
#[inline]
pub fn intersect_count_below(a: &[VertexId], b: &[VertexId], bound: VertexId) -> usize {
    let a = &a[..a.partition_point(|&x| x < bound)];
    let b = &b[..b.partition_point(|&x| x < bound)];
    intersect_count(a, b)
}

/// {x ∈ a ∩ b : x < bound} appended to `out`; bound fused as in
/// [`intersect_count_below`].
#[inline]
pub fn intersect_into_below(
    a: &[VertexId],
    b: &[VertexId],
    bound: VertexId,
    out: &mut Vec<VertexId>,
) {
    let a = &a[..a.partition_point(|&x| x < bound)];
    let b = &b[..b.partition_point(|&x| x < bound)];
    intersect_into(a, b, out)
}

/// a \ b (anti-intersection) appended to `out`, for non-adjacency
/// constraints of vertex-induced matching. Adaptive: when `b` is much
/// longer than `a`, each element of `a` is binary-searched in a
/// shrinking window of `b` instead of merging.
pub fn difference_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    dispatch::note_difference();
    if skewed(a.len(), b.len()) {
        let mut lo = 0usize;
        for (i, &x) in a.iter().enumerate() {
            if lo >= b.len() {
                out.extend_from_slice(&a[i..]);
                return;
            }
            match b[lo..].binary_search(&x) {
                Ok(pos) => lo += pos + 1,
                Err(pos) => {
                    lo += pos;
                    out.push(x);
                }
            }
        }
        return;
    }
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        let (x, y) = (a[i], b[j]);
        if x == y {
            i += 1;
            j += 1;
        } else if x < y {
            out.push(x);
            i += 1;
        } else {
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
}

// ---------------------------------------------------------------------------
// Bitset / word-parallel kernels
// ---------------------------------------------------------------------------

/// Keep only the elements of `v` present in `bits` (in-place bitset
/// intersection; order preserved, no allocation).
pub fn retain_in_bitset(v: &mut Vec<VertexId>, bits: &BitSet) {
    let mut w = 0usize;
    for i in 0..v.len() {
        let x = v[i];
        if bits.contains(x as usize) {
            v[w] = x;
            w += 1;
        }
    }
    v.truncate(w);
}

/// Keep only the elements of `v` absent from `bits` (in-place bitset
/// anti-intersection).
pub fn retain_not_in_bitset(v: &mut Vec<VertexId>, bits: &BitSet) {
    let mut w = 0usize;
    for i in 0..v.len() {
        let x = v[i];
        if !bits.contains(x as usize) {
            v[w] = x;
            w += 1;
        }
    }
    v.truncate(w);
}

/// |a ∩ bits| via O(1) membership probes.
pub fn intersect_bitset_count(a: &[VertexId], bits: &BitSet) -> usize {
    a.iter().filter(|&&x| bits.contains(x as usize)).count()
}

/// Word-parallel intersection count of two bit vectors: AND + popcount,
/// 64 memberships per instruction pair (hardware `popcnt` when the CPU
/// has it). Both slices must cover the same universe; trailing words of
/// the longer slice are ignored.
pub fn intersect_words_count(a: &[u64], b: &[u64]) -> usize {
    dispatch::note_word_parallel();
    #[cfg(target_arch = "x86_64")]
    if popcnt_enabled() {
        // SAFETY: POPCNT support verified by runtime detection.
        return unsafe { x86::words_and_count_popcnt(a, b) };
    }
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| (x & y).count_ones() as usize)
        .sum()
}

/// Word-parallel AND of two bit vectors with the set bits of the result
/// decoded (ascending) onto `out` — the bitset×bitset dense-frontier
/// kernel: the AND runs 64 memberships per instruction pair and only
/// surviving candidates pay the bit-extraction cost. Trailing words of
/// the longer slice are ignored.
pub fn and_words_into(a: &[u64], b: &[u64], out: &mut Vec<VertexId>) {
    dispatch::note_word_parallel();
    for (wi, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let mut w = x & y;
        while w != 0 {
            let bit = w.trailing_zeros() as usize;
            out.push((wi * 64 + bit) as VertexId);
            w &= w - 1;
        }
    }
}

/// Word-parallel AND-NOT of two bit vectors with the set bits of
/// `a & !b` decoded (ascending) onto `out` — the bitset×bitset dense
/// *anti*-intersection kernel behind the extension core's
/// exclusive-neighbor construction (ESU, PR 5): the candidate bitmap is
/// swept against the coverage bitmap 64 memberships per instruction
/// pair, and only survivors pay the bit-extraction cost. Words of `a`
/// past the end of `b` are treated as uncovered (they survive whole).
pub fn andnot_words_into(a: &[u64], b: &[u64], out: &mut Vec<VertexId>) {
    dispatch::note_word_parallel();
    for (wi, &x) in a.iter().enumerate() {
        let y = b.get(wi).copied().unwrap_or(0);
        let mut w = x & !y;
        while w != 0 {
            let bit = w.trailing_zeros() as usize;
            out.push((wi * 64 + bit) as VertexId);
            w &= w - 1;
        }
    }
}

/// Scan a contiguous range of 32-bit constraint masks, appending
/// `base + index` for every mask `m` with `m & want == want` and
/// `m & veto == 0` — the LG dense-mode candidate scan over the
/// embedding-adjacency array (vectorized 8 masks per compare on AVX2).
pub fn mask_filter_into(masks: &[u32], base: u32, want: u32, veto: u32, out: &mut Vec<u32>) {
    dispatch::note_mask_filter();
    #[cfg(target_arch = "x86_64")]
    if simd_level() == SimdLevel::Avx2 && masks.len() >= 16 {
        // SAFETY: AVX2 support verified by runtime detection.
        return unsafe { x86::mask_filter_avx2(masks, base, want, veto, out) };
    }
    for (k, &m) in masks.iter().enumerate() {
        if m & want == want && m & veto == 0 {
            // wrapping, matching the AVX2 kernel's id arithmetic, so the
            // two paths agree on every input
            out.push(base.wrapping_add(k as u32));
        }
    }
}

/// Gather `codes[key]` for every key and append the keys whose code `c`
/// satisfies `c & want == want && c & veto == 0` (input order kept) —
/// the MNC dense-mode connectivity filter (AVX2 `vpgatherdd` when
/// available). Keys must index into `codes`; out-of-range keys panic
/// exactly as slice indexing does.
pub fn gather_mask_filter_into(
    codes: &[u32],
    keys: &[VertexId],
    want: u32,
    veto: u32,
    out: &mut Vec<VertexId>,
) {
    dispatch::note_gather_filter();
    #[cfg(target_arch = "x86_64")]
    if simd_level() == SimdLevel::Avx2 && keys.len() >= 16 {
        // SAFETY: AVX2 support verified by runtime detection; the
        // kernel bounds-checks each block before gathering.
        return unsafe { x86::gather_filter_avx2(codes, keys, want, veto, out) };
    }
    for &u in keys {
        let c = codes[u as usize];
        if c & want == want && c & veto == 0 {
            out.push(u);
        }
    }
}

/// Count elements of sorted `a` strictly less than `bound` (for symmetry
/// breaking bounded intersections).
#[inline]
pub fn count_less_than(a: &[VertexId], bound: VertexId) -> usize {
    a.partition_point(|&x| x < bound)
}

// ---------------------------------------------------------------------------
// Scalar reference kernels (also the SIMD tails and differential oracle)
// ---------------------------------------------------------------------------

/// Linear-merge intersection count (branch-light lockstep walk). Public
/// as the scalar reference the SIMD kernels are differentially tested
/// against; normal callers use the adaptive [`intersect_count`].
#[inline]
pub fn merge_count(a: &[VertexId], b: &[VertexId]) -> usize {
    let (mut i, mut j, mut n) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        let (x, y) = (a[i], b[j]);
        i += (x <= y) as usize;
        j += (y <= x) as usize;
        n += (x == y) as usize;
    }
    n
}

/// Linear-merge intersection appended to `out`. Public as the scalar
/// reference for differential tests; normal callers use the adaptive
/// [`intersect_into`].
#[inline]
pub fn merge_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        let (x, y) = (a[i], b[j]);
        if x == y {
            out.push(x);
            i += 1;
            j += 1;
        } else if x < y {
            i += 1;
        } else {
            j += 1;
        }
    }
}

/// Count |a ∩ b| by binary-searching each element of the short list `a`
/// in the long list `b`, narrowing the search window as we go.
fn gallop_count(a: &[VertexId], b: &[VertexId]) -> usize {
    let mut lo = 0usize;
    let mut n = 0usize;
    for &x in a {
        match b[lo..].binary_search(&x) {
            Ok(pos) => {
                n += 1;
                lo += pos + 1;
            }
            Err(pos) => lo += pos,
        }
        if lo >= b.len() {
            break;
        }
    }
    n
}

/// Galloping intersection appended to `out` (`a` is the short list).
fn gallop_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    let mut lo = 0usize;
    for &x in a {
        match b[lo..].binary_search(&x) {
            Ok(pos) => {
                out.push(x);
                lo += pos + 1;
            }
            Err(pos) => lo += pos,
        }
        if lo >= b.len() {
            break;
        }
    }
}

// ---------------------------------------------------------------------------
// x86_64 kernels (runtime-dispatched; every function has a scalar twin)
// ---------------------------------------------------------------------------

/// `std::arch` x86_64 kernels. All functions are `unsafe` because they
/// require the CPU feature named in their `#[target_feature]`; the safe
/// dispatchers above verify it at runtime before calling. Block-merge
/// correctness rests on the module-wide contract (sorted, duplicate-free
/// inputs): comparing one block of each list all-pairs and advancing the
/// block with the smaller maximum visits every equal pair exactly once.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::uninit_vec)] // spare capacity is written via `storeu` before every `set_len`
mod x86 {
    use std::arch::x86_64::*;

    /// Shuffle-control LUT for SSE lane compaction: entry `m` moves the
    /// 32-bit lanes whose bit is set in `m` to the front, in order
    /// (0x80 bytes zero the rest, which `set_len` never exposes).
    const fn sse_compact_table() -> [[u8; 16]; 16] {
        let mut t = [[0x80u8; 16]; 16];
        let mut m = 0usize;
        while m < 16 {
            let mut out = 0usize;
            let mut lane = 0usize;
            while lane < 4 {
                if m & (1 << lane) != 0 {
                    let mut b = 0usize;
                    while b < 4 {
                        t[m][out * 4 + b] = (lane * 4 + b) as u8;
                        b += 1;
                    }
                    out += 1;
                }
                lane += 1;
            }
            m += 1;
        }
        t
    }
    static SSE_COMPACT: [[u8; 16]; 16] = sse_compact_table();

    /// Permute-index LUT for AVX2 lane compaction: entry `m` lists the
    /// set-bit lane indices of `m` first (tail lanes are ignored —
    /// `set_len` only advances by popcount(m)).
    const fn avx2_compact_table() -> [[u32; 8]; 256] {
        let mut t = [[0u32; 8]; 256];
        let mut m = 0usize;
        while m < 256 {
            let mut out = 0usize;
            let mut lane = 0usize;
            while lane < 8 {
                if m & (1 << lane) != 0 {
                    t[m][out] = lane as u32;
                    out += 1;
                }
                lane += 1;
            }
            m += 1;
        }
        t
    }
    static AVX2_COMPACT: [[u32; 8]; 256] = avx2_compact_table();

    /// Lane-rotation index vectors for the AVX2 all-pairs compare:
    /// row r-1 rotates the block left by r lanes.
    static AVX2_ROTATIONS: [[i32; 8]; 7] = [
        [1, 2, 3, 4, 5, 6, 7, 0],
        [2, 3, 4, 5, 6, 7, 0, 1],
        [3, 4, 5, 6, 7, 0, 1, 2],
        [4, 5, 6, 7, 0, 1, 2, 3],
        [5, 6, 7, 0, 1, 2, 3, 4],
        [6, 7, 0, 1, 2, 3, 4, 5],
        [7, 0, 1, 2, 3, 4, 5, 6],
    ];

    /// Bitmask of `va` lanes equal to any lane of `vb` (4-lane blocks;
    /// three 32-bit rotations cover all pairs).
    ///
    /// # Safety
    /// The CPU must support SSSE3 (every caller is itself an
    /// SSSE3 `#[target_feature]` kernel reached only through the
    /// runtime-detecting dispatcher).
    #[inline]
    #[target_feature(enable = "ssse3")]
    unsafe fn sse_match_mask(va: __m128i, vb: __m128i) -> u32 {
        let c0 = _mm_cmpeq_epi32(va, vb);
        let c1 = _mm_cmpeq_epi32(va, _mm_shuffle_epi32::<0b00_11_10_01>(vb));
        let c2 = _mm_cmpeq_epi32(va, _mm_shuffle_epi32::<0b01_00_11_10>(vb));
        let c3 = _mm_cmpeq_epi32(va, _mm_shuffle_epi32::<0b10_01_00_11>(vb));
        let any = _mm_or_si128(_mm_or_si128(c0, c1), _mm_or_si128(c2, c3));
        _mm_movemask_ps(_mm_castsi128_ps(any)) as u32
    }

    /// SSE block-merge intersection count; scalar merge finishes the
    /// ragged tails.
    ///
    /// # Safety
    /// The CPU must support SSSE3 (runtime-checked by the dispatcher).
    #[target_feature(enable = "ssse3")]
    pub unsafe fn intersect_count_sse(a: &[u32], b: &[u32]) -> usize {
        let (mut i, mut j, mut n) = (0usize, 0usize, 0usize);
        let a4 = a.len() & !3;
        let b4 = b.len() & !3;
        while i < a4 && j < b4 {
            let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(j) as *const __m128i);
            n += sse_match_mask(va, vb).count_ones() as usize;
            let a_max = *a.get_unchecked(i + 3);
            let b_max = *b.get_unchecked(j + 3);
            i += ((a_max <= b_max) as usize) << 2;
            j += ((b_max <= a_max) as usize) << 2;
        }
        n + super::merge_count(&a[i..], &b[j..])
    }

    /// SSE block-merge intersection appended to `out` (shuffle-LUT lane
    /// compaction); scalar merge finishes the ragged tails.
    ///
    /// # Safety
    /// The CPU must support SSSE3 (runtime-checked by the dispatcher).
    #[target_feature(enable = "ssse3")]
    pub unsafe fn intersect_into_sse(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
        let (mut i, mut j) = (0usize, 0usize);
        let a4 = a.len() & !3;
        let b4 = b.len() & !3;
        while i < a4 && j < b4 {
            let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(j) as *const __m128i);
            let mask = sse_match_mask(va, vb);
            if mask != 0 {
                let shuf = _mm_loadu_si128(SSE_COMPACT[mask as usize].as_ptr() as *const __m128i);
                let packed = _mm_shuffle_epi8(va, shuf);
                out.reserve(4);
                let len = out.len();
                _mm_storeu_si128(out.as_mut_ptr().add(len) as *mut __m128i, packed);
                out.set_len(len + mask.count_ones() as usize);
            }
            let a_max = *a.get_unchecked(i + 3);
            let b_max = *b.get_unchecked(j + 3);
            i += ((a_max <= b_max) as usize) << 2;
            j += ((b_max <= a_max) as usize) << 2;
        }
        super::merge_into(&a[i..], &b[j..], out);
    }

    /// Bitmask of `va` lanes equal to any lane of `vb` (8-lane blocks;
    /// seven cross-lane rotations cover all pairs).
    ///
    /// # Safety
    /// The CPU must support AVX2 (every caller is itself an
    /// AVX2 `#[target_feature]` kernel reached only through the
    /// runtime-detecting dispatcher).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn avx2_match_mask(va: __m256i, vb: __m256i) -> u32 {
        let mut any = _mm256_cmpeq_epi32(va, vb);
        for rot in &AVX2_ROTATIONS {
            let idx = _mm256_loadu_si256(rot.as_ptr() as *const __m256i);
            let rotated = _mm256_permutevar8x32_epi32(vb, idx);
            any = _mm256_or_si256(any, _mm256_cmpeq_epi32(va, rotated));
        }
        _mm256_movemask_ps(_mm256_castsi256_ps(any)) as u32
    }

    /// AVX2 block-merge intersection count; scalar merge finishes the
    /// ragged tails.
    ///
    /// # Safety
    /// The CPU must support AVX2 (runtime-checked by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn intersect_count_avx2(a: &[u32], b: &[u32]) -> usize {
        let (mut i, mut j, mut n) = (0usize, 0usize, 0usize);
        let a8 = a.len() & !7;
        let b8 = b.len() & !7;
        while i < a8 && j < b8 {
            let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(j) as *const __m256i);
            n += avx2_match_mask(va, vb).count_ones() as usize;
            let a_max = *a.get_unchecked(i + 7);
            let b_max = *b.get_unchecked(j + 7);
            i += ((a_max <= b_max) as usize) << 3;
            j += ((b_max <= a_max) as usize) << 3;
        }
        n + super::merge_count(&a[i..], &b[j..])
    }

    /// AVX2 block-merge intersection appended to `out` (permute-LUT
    /// lane compaction); scalar merge finishes the ragged tails.
    ///
    /// # Safety
    /// The CPU must support AVX2 (runtime-checked by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn intersect_into_avx2(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
        let (mut i, mut j) = (0usize, 0usize);
        let a8 = a.len() & !7;
        let b8 = b.len() & !7;
        while i < a8 && j < b8 {
            let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(j) as *const __m256i);
            let mask = avx2_match_mask(va, vb);
            if mask != 0 {
                let idx =
                    _mm256_loadu_si256(AVX2_COMPACT[mask as usize].as_ptr() as *const __m256i);
                let packed = _mm256_permutevar8x32_epi32(va, idx);
                out.reserve(8);
                let len = out.len();
                _mm256_storeu_si256(out.as_mut_ptr().add(len) as *mut __m256i, packed);
                out.set_len(len + mask.count_ones() as usize);
            }
            let a_max = *a.get_unchecked(i + 7);
            let b_max = *b.get_unchecked(j + 7);
            i += ((a_max <= b_max) as usize) << 3;
            j += ((b_max <= a_max) as usize) << 3;
        }
        super::merge_into(&a[i..], &b[j..], out);
    }

    /// AND + hardware popcount over word pairs.
    ///
    /// # Safety
    /// The CPU must support POPCNT (runtime-checked by the dispatcher).
    #[target_feature(enable = "popcnt")]
    pub unsafe fn words_and_count_popcnt(a: &[u64], b: &[u64]) -> usize {
        a.iter()
            .zip(b.iter())
            .map(|(&x, &y)| (x & y).count_ones() as usize)
            .sum()
    }

    /// AVX2 mask-range scan: 8 constraint tests per compare, matched
    /// indices compacted through the permute LUT.
    ///
    /// # Safety
    /// The CPU must support AVX2 (runtime-checked by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn mask_filter_avx2(
        masks: &[u32],
        base: u32,
        want: u32,
        veto: u32,
        out: &mut Vec<u32>,
    ) {
        let vwant = _mm256_set1_epi32(want as i32);
        let vveto = _mm256_set1_epi32(veto as i32);
        let vzero = _mm256_setzero_si256();
        let lanes = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
        let n8 = masks.len() & !7;
        let mut k = 0usize;
        while k < n8 {
            let vm = _mm256_loadu_si256(masks.as_ptr().add(k) as *const __m256i);
            let adj_ok = _mm256_cmpeq_epi32(_mm256_and_si256(vm, vwant), vwant);
            let veto_ok = _mm256_cmpeq_epi32(_mm256_and_si256(vm, vveto), vzero);
            let ok = _mm256_and_si256(adj_ok, veto_ok);
            let mask = _mm256_movemask_ps(_mm256_castsi256_ps(ok)) as u32;
            if mask != 0 {
                let ids = _mm256_add_epi32(
                    _mm256_set1_epi32(base.wrapping_add(k as u32) as i32),
                    lanes,
                );
                let idx =
                    _mm256_loadu_si256(AVX2_COMPACT[mask as usize].as_ptr() as *const __m256i);
                let packed = _mm256_permutevar8x32_epi32(ids, idx);
                out.reserve(8);
                let len = out.len();
                _mm256_storeu_si256(out.as_mut_ptr().add(len) as *mut __m256i, packed);
                out.set_len(len + mask.count_ones() as usize);
            }
            k += 8;
        }
        for (k2, &m) in masks.iter().enumerate().skip(n8) {
            if m & want == want && m & veto == 0 {
                out.push(base.wrapping_add(k2 as u32));
            }
        }
    }

    /// AVX2 gathered code filter: `vpgatherdd` fetches 8 codes per
    /// block; a block with any out-of-range key falls back to the
    /// bounds-checked scalar loop (panics exactly like slice indexing).
    ///
    /// # Safety
    /// The CPU must support AVX2 (runtime-checked by the dispatcher).
    #[target_feature(enable = "avx2")]
    pub unsafe fn gather_filter_avx2(
        codes: &[u32],
        keys: &[u32],
        want: u32,
        veto: u32,
        out: &mut Vec<u32>,
    ) {
        let vwant = _mm256_set1_epi32(want as i32);
        let vveto = _mm256_set1_epi32(veto as i32);
        let vzero = _mm256_setzero_si256();
        let vlen = _mm256_set1_epi32(codes.len().min(i32::MAX as usize) as i32);
        let vneg1 = _mm256_set1_epi32(-1);
        let n8 = keys.len() & !7;
        let mut k = 0usize;
        while k < n8 {
            let vkeys = _mm256_loadu_si256(keys.as_ptr().add(k) as *const __m256i);
            // in-bounds check per block: 0 <= key < codes.len() as i32
            let below = _mm256_cmpgt_epi32(vlen, vkeys);
            let nonneg = _mm256_cmpgt_epi32(vkeys, vneg1);
            let inb = _mm256_and_si256(below, nonneg);
            if _mm256_movemask_ps(_mm256_castsi256_ps(inb)) as u32 != 0xFF {
                for &u in &keys[k..k + 8] {
                    let c = codes[u as usize];
                    if c & want == want && c & veto == 0 {
                        out.push(u);
                    }
                }
                k += 8;
                continue;
            }
            let vcodes = _mm256_i32gather_epi32::<4>(codes.as_ptr() as *const i32, vkeys);
            let adj_ok = _mm256_cmpeq_epi32(_mm256_and_si256(vcodes, vwant), vwant);
            let veto_ok = _mm256_cmpeq_epi32(_mm256_and_si256(vcodes, vveto), vzero);
            let ok = _mm256_and_si256(adj_ok, veto_ok);
            let mask = _mm256_movemask_ps(_mm256_castsi256_ps(ok)) as u32;
            if mask != 0 {
                let idx =
                    _mm256_loadu_si256(AVX2_COMPACT[mask as usize].as_ptr() as *const __m256i);
                let packed = _mm256_permutevar8x32_epi32(vkeys, idx);
                out.reserve(8);
                let len = out.len();
                _mm256_storeu_si256(out.as_mut_ptr().add(len) as *mut __m256i, packed);
                out.set_len(len + mask.count_ones() as usize);
            }
            k += 8;
        }
        for &u in &keys[n8..] {
            let c = codes[u as usize];
            if c & want == want && c & veto == 0 {
                out.push(u);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
        a.iter().copied().filter(|x| b.contains(x)).collect()
    }

    #[test]
    fn empty_disjoint_identical() {
        let a: Vec<u32> = vec![1, 3, 5];
        let empty: Vec<u32> = vec![];
        assert_eq!(intersect_count(&a, &empty), 0);
        assert_eq!(intersect_count(&empty, &a), 0);
        assert_eq!(intersect_count(&empty, &empty), 0);
        let b: Vec<u32> = vec![2, 4, 6];
        assert_eq!(intersect_count(&a, &b), 0);
        assert_eq!(intersect_count(&a, &a), 3);
        let mut out = Vec::new();
        intersect_into(&a, &a, &mut out);
        assert_eq!(out, a);
    }

    #[test]
    fn gallop_matches_merge_on_skewed_lists() {
        let long: Vec<u32> = (0..2000).step_by(3).collect();
        let short: Vec<u32> = vec![0, 3, 4, 600, 601, 1998];
        assert!(skewed(short.len(), long.len()));
        let want = naive_intersect(&short, &long);
        assert_eq!(intersect_count(&short, &long), want.len());
        assert_eq!(intersect_count(&long, &short), want.len());
        let mut out = Vec::new();
        intersect_into(&short, &long, &mut out);
        assert_eq!(out, want);
        out.clear();
        intersect_into(&long, &short, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn bounded_below_all_and_above_all() {
        let a: Vec<u32> = vec![10, 20, 30];
        let b: Vec<u32> = vec![10, 25, 30];
        // bound below every element: empty result
        assert_eq!(intersect_count_below(&a, &b, 5), 0);
        let mut out = Vec::new();
        intersect_into_below(&a, &b, 5, &mut out);
        assert!(out.is_empty());
        // bound above every element: same as unbounded
        assert_eq!(intersect_count_below(&a, &b, 1000), 2);
        intersect_into_below(&a, &b, 1000, &mut out);
        assert_eq!(out, vec![10, 30]);
        // bound is exclusive
        assert_eq!(intersect_count_below(&a, &b, 30), 1);
        out.clear();
        intersect_into_below(&a, &b, 30, &mut out);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn difference_edge_cases() {
        let a: Vec<u32> = vec![1, 2, 3, 4];
        let empty: Vec<u32> = vec![];
        let mut out = Vec::new();
        difference_into(&a, &empty, &mut out);
        assert_eq!(out, a);
        out.clear();
        difference_into(&empty, &a, &mut out);
        assert!(out.is_empty());
        out.clear();
        difference_into(&a, &a, &mut out);
        assert!(out.is_empty());
        out.clear();
        difference_into(&a, &[2, 4], &mut out);
        assert_eq!(out, vec![1, 3]);
    }

    #[test]
    fn andnot_words_decodes_survivors_ascending() {
        use crate::util::bitset::BitSet;
        let n = 200usize;
        let mut a = BitSet::new(n);
        let mut b = BitSet::new(n);
        for i in (0..n).step_by(3) {
            a.insert(i);
        }
        for i in (0..n).step_by(5) {
            b.insert(i);
        }
        let mut got = Vec::new();
        andnot_words_into(a.words(), b.words(), &mut got);
        let want: Vec<u32> =
            (0..n).step_by(3).filter(|i| i % 5 != 0).map(|i| i as u32).collect();
        assert_eq!(got, want);
        // a longer than b: the uncovered tail survives whole
        let mut tail = Vec::new();
        andnot_words_into(a.words(), &b.words()[..1], &mut tail);
        let want_tail: Vec<u32> = (0..n)
            .step_by(3)
            .filter(|&i| i >= 64 || i % 5 != 0)
            .map(|i| i as u32)
            .collect();
        assert_eq!(tail, want_tail);
        // empty inputs are no-ops
        let mut none = Vec::new();
        andnot_words_into(&[], b.words(), &mut none);
        assert!(none.is_empty());
    }

    #[test]
    fn difference_gallop_matches_merge() {
        let long: Vec<u32> = (0..3000).step_by(2).collect();
        let short: Vec<u32> = vec![0, 1, 100, 101, 2998, 2999, 5000];
        assert!(skewed(short.len(), long.len()));
        let mut got = Vec::new();
        difference_into(&short, &long, &mut got);
        let want: Vec<u32> =
            short.iter().copied().filter(|x| !long.contains(x)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn bitset_filters_match_list_kernels() {
        let a: Vec<u32> = vec![0, 5, 63, 64, 65, 199];
        let b: Vec<u32> = vec![5, 64, 199, 200];
        let mut bits = BitSet::new(256);
        for &x in &b {
            bits.insert(x as usize);
        }
        assert_eq!(intersect_bitset_count(&a, &bits), intersect_count(&a, &b));
        let mut keep = a.clone();
        retain_in_bitset(&mut keep, &bits);
        assert_eq!(keep, naive_intersect(&a, &b));
        let mut drop = a.clone();
        retain_not_in_bitset(&mut drop, &bits);
        let mut want = Vec::new();
        difference_into(&a, &b, &mut want);
        assert_eq!(drop, want);
    }

    #[test]
    fn word_parallel_count() {
        let mut x = BitSet::new(300);
        let mut y = BitSet::new(300);
        for i in [1usize, 64, 65, 130, 299] {
            x.insert(i);
        }
        for i in [1usize, 65, 131, 299] {
            y.insert(i);
        }
        assert_eq!(intersect_words_count(x.words(), y.words()), 3);
        assert_eq!(intersect_words_count(x.words(), x.words()), 5);
        assert_eq!(intersect_words_count(&[], y.words()), 0);
    }

    #[test]
    fn and_words_decodes_sorted_survivors() {
        let mut x = BitSet::new(300);
        let mut y = BitSet::new(300);
        for i in [1usize, 64, 65, 130, 299] {
            x.insert(i);
        }
        for i in [1usize, 65, 131, 299] {
            y.insert(i);
        }
        let mut got: Vec<u32> = Vec::new();
        and_words_into(x.words(), y.words(), &mut got);
        assert_eq!(got, vec![1, 65, 299]);
        got.clear();
        and_words_into(&[], y.words(), &mut got);
        assert!(got.is_empty());
    }

    #[test]
    fn simd_merge_matches_scalar_reference_when_available() {
        // exercised regardless of host CPU: the dispatcher falls back
        // to the scalar kernels when no vector feature is detected
        let a: Vec<u32> = (0..200).step_by(3).collect();
        let b: Vec<u32> = (0..200).step_by(2).collect();
        assert!(a.len() >= SIMD_MIN_LEN && b.len() >= SIMD_MIN_LEN);
        assert_eq!(intersect_count(&a, &b), merge_count(&a, &b));
        let mut got = Vec::new();
        intersect_into(&a, &b, &mut got);
        let mut want = Vec::new();
        merge_into(&a, &b, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn mask_filter_matches_scalar_loop() {
        let masks: Vec<u32> = (0..100u32).map(|k| k % 8).collect();
        let (want_bits, veto_bits) = (0b001u32, 0b100u32);
        let mut got = Vec::new();
        mask_filter_into(&masks, 10, want_bits, veto_bits, &mut got);
        let want: Vec<u32> = masks
            .iter()
            .enumerate()
            .filter(|(_, &m)| m & want_bits == want_bits && m & veto_bits == 0)
            .map(|(k, _)| 10 + k as u32)
            .collect();
        assert_eq!(got, want);
        // empty range
        got.clear();
        mask_filter_into(&[], 0, 1, 0, &mut got);
        assert!(got.is_empty());
    }

    #[test]
    fn gather_filter_matches_scalar_loop() {
        let codes: Vec<u32> = (0..256u32).map(|k| (k * 7) % 16).collect();
        let keys: Vec<u32> = (0..256).step_by(3).collect();
        let (want_bits, veto_bits) = (0b0010u32, 0b1000u32);
        let mut got = Vec::new();
        gather_mask_filter_into(&codes, &keys, want_bits, veto_bits, &mut got);
        let want: Vec<u32> = keys
            .iter()
            .copied()
            .filter(|&u| {
                let c = codes[u as usize];
                c & want_bits == want_bits && c & veto_bits == 0
            })
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn simd_mode_reports_consistently() {
        // whatever the host supports, level and name must agree, and the
        // kill switch must force (and then release) the scalar level
        let detected = simd_level();
        match detected {
            SimdLevel::Avx2 => assert_eq!(simd_level_name(), "avx2"),
            SimdLevel::Sse => assert_eq!(simd_level_name(), "ssse3"),
            SimdLevel::Scalar => assert_eq!(simd_level_name(), "scalar"),
        }
        set_simd_enabled(false);
        assert_eq!(simd_level(), SimdLevel::Scalar);
        assert!(!simd_active());
        set_simd_enabled(true);
        assert_eq!(simd_level(), detected);
    }

    #[test]
    fn count_less_than_bounds() {
        let a = vec![1u32, 3, 5, 7];
        assert_eq!(count_less_than(&a, 0), 0);
        assert_eq!(count_less_than(&a, 4), 2);
        assert_eq!(count_less_than(&a, 100), 4);
    }
}
