//! Set-operation kernels — the single tuned implementation of sorted-set
//! intersection / difference in the system.
//!
//! Sandslash's performance hinges on fast subgraph extension (paper
//! §4–§5): MNC and LG exist precisely to replace per-candidate edge
//! probes with set operations, and every fast path (TC, k-CL, SL, the
//! set-centric DFS frontier) bottoms out here. Three kernel families,
//! chosen adaptively by length/density heuristics (crossovers recorded
//! in EXPERIMENTS.md):
//!
//! * **linear merge** — both lists walked in lockstep; best when the
//!   lengths are within ~[`GALLOP_FACTOR`] of each other.
//! * **galloping** — each element of the short list binary-searched in a
//!   shrinking window of the long list; wins when the lengths are skewed
//!   by more than [`GALLOP_FACTOR`].
//! * **bitset filter** — O(1) word-indexed membership probes against a
//!   pre-built neighborhood bitmap ([`BitSet`]); wins when one operand
//!   is reused across many operations (e.g. a high-degree root's
//!   neighborhood, built once per root task and probed at every level).
//!
//! Bounded variants (`*_below`) fuse a symmetry-breaking upper bound
//! into the kernel so candidates violating `cand < bound` are never
//! even visited — the DFS frontier achieves the same fusion by slicing
//! its seed list, these are for callers intersecting directly;
//! [`difference_into`] is the anti-intersection needed by
//! vertex-induced (non-adjacency) constraints.
//!
//! All kernels operate on sorted, duplicate-free slices (CSR neighbor
//! rows are maintained that way by construction):
//!
//! ```
//! use sandslash::graph::setops;
//!
//! let a: Vec<u32> = vec![1, 3, 5, 7];
//! let b: Vec<u32> = vec![3, 4, 5, 9];
//! assert_eq!(setops::intersect_count(&a, &b), 2);
//!
//! let mut out = Vec::new();
//! setops::intersect_into(&a, &b, &mut out);
//! assert_eq!(out, vec![3, 5]);
//!
//! // symmetry-breaking bound fused: elements >= 5 are never visited
//! assert_eq!(setops::intersect_count_below(&a, &b, 5), 1);
//!
//! // anti-intersection for vertex-induced (non-edge) constraints
//! out.clear();
//! setops::difference_into(&a, &b, &mut out);
//! assert_eq!(out, vec![1, 7]);
//! ```

use super::csr::VertexId;
use crate::util::bitset::BitSet;

/// Length-skew crossover between linear merge and galloping: gallop when
/// `short * GALLOP_FACTOR < long`. The merge costs O(short + long), the
/// gallop O(short * log(long)); 32 puts the switch safely past the point
/// where the binary-search branch misses stop paying for themselves
/// (measured in the §Perf pass, see EXPERIMENTS.md).
pub const GALLOP_FACTOR: usize = 32;

#[inline]
fn skewed(short: usize, long: usize) -> bool {
    short * GALLOP_FACTOR < long
}

/// |a ∩ b| for sorted slices; adaptive merge/gallop.
#[inline]
pub fn intersect_count(a: &[VertexId], b: &[VertexId]) -> usize {
    if skewed(a.len(), b.len()) {
        return gallop_count(a, b);
    }
    if skewed(b.len(), a.len()) {
        return gallop_count(b, a);
    }
    merge_count(a, b)
}

/// a ∩ b appended to `out` (not cleared); adaptive merge/gallop.
#[inline]
pub fn intersect_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    if skewed(a.len(), b.len()) {
        return gallop_into(a, b, out);
    }
    if skewed(b.len(), a.len()) {
        return gallop_into(b, a, out);
    }
    merge_into(a, b, out)
}

/// |{x ∈ a ∩ b : x < bound}| with the bound fused into the kernel: both
/// inputs are pre-truncated by binary search, so elements ≥ bound are
/// never visited (symmetry-breaking `lt` constraints).
#[inline]
pub fn intersect_count_below(a: &[VertexId], b: &[VertexId], bound: VertexId) -> usize {
    let a = &a[..a.partition_point(|&x| x < bound)];
    let b = &b[..b.partition_point(|&x| x < bound)];
    intersect_count(a, b)
}

/// {x ∈ a ∩ b : x < bound} appended to `out`; bound fused as in
/// [`intersect_count_below`].
#[inline]
pub fn intersect_into_below(
    a: &[VertexId],
    b: &[VertexId],
    bound: VertexId,
    out: &mut Vec<VertexId>,
) {
    let a = &a[..a.partition_point(|&x| x < bound)];
    let b = &b[..b.partition_point(|&x| x < bound)];
    intersect_into(a, b, out)
}

/// a \ b (anti-intersection) appended to `out`, for non-adjacency
/// constraints of vertex-induced matching. Adaptive: when `b` is much
/// longer than `a`, each element of `a` is binary-searched in a
/// shrinking window of `b` instead of merging.
pub fn difference_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    if skewed(a.len(), b.len()) {
        let mut lo = 0usize;
        for (i, &x) in a.iter().enumerate() {
            if lo >= b.len() {
                out.extend_from_slice(&a[i..]);
                return;
            }
            match b[lo..].binary_search(&x) {
                Ok(pos) => lo += pos + 1,
                Err(pos) => {
                    lo += pos;
                    out.push(x);
                }
            }
        }
        return;
    }
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        let (x, y) = (a[i], b[j]);
        if x == y {
            i += 1;
            j += 1;
        } else if x < y {
            out.push(x);
            i += 1;
        } else {
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
}

/// Keep only the elements of `v` present in `bits` (in-place bitset
/// intersection; order preserved, no allocation).
pub fn retain_in_bitset(v: &mut Vec<VertexId>, bits: &BitSet) {
    let mut w = 0usize;
    for i in 0..v.len() {
        let x = v[i];
        if bits.contains(x as usize) {
            v[w] = x;
            w += 1;
        }
    }
    v.truncate(w);
}

/// Keep only the elements of `v` absent from `bits` (in-place bitset
/// anti-intersection).
pub fn retain_not_in_bitset(v: &mut Vec<VertexId>, bits: &BitSet) {
    let mut w = 0usize;
    for i in 0..v.len() {
        let x = v[i];
        if !bits.contains(x as usize) {
            v[w] = x;
            w += 1;
        }
    }
    v.truncate(w);
}

/// |a ∩ bits| via O(1) membership probes.
pub fn intersect_bitset_count(a: &[VertexId], bits: &BitSet) -> usize {
    a.iter().filter(|&&x| bits.contains(x as usize)).count()
}

/// Word-parallel intersection count of two bit vectors: AND + popcount,
/// 64 memberships per instruction pair. Both slices must cover the same
/// universe; trailing words of the longer slice are ignored.
pub fn intersect_words_count(a: &[u64], b: &[u64]) -> usize {
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| (x & y).count_ones() as usize)
        .sum()
}

/// Count elements of sorted `a` strictly less than `bound` (for symmetry
/// breaking bounded intersections).
#[inline]
pub fn count_less_than(a: &[VertexId], bound: VertexId) -> usize {
    a.partition_point(|&x| x < bound)
}

/// Linear-merge intersection count (branch-light lockstep walk).
#[inline]
fn merge_count(a: &[VertexId], b: &[VertexId]) -> usize {
    let (mut i, mut j, mut n) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        let (x, y) = (a[i], b[j]);
        i += (x <= y) as usize;
        j += (y <= x) as usize;
        n += (x == y) as usize;
    }
    n
}

/// Linear-merge intersection appended to `out`.
#[inline]
fn merge_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        let (x, y) = (a[i], b[j]);
        if x == y {
            out.push(x);
            i += 1;
            j += 1;
        } else if x < y {
            i += 1;
        } else {
            j += 1;
        }
    }
}

/// Count |a ∩ b| by binary-searching each element of the short list `a`
/// in the long list `b`, narrowing the search window as we go.
fn gallop_count(a: &[VertexId], b: &[VertexId]) -> usize {
    let mut lo = 0usize;
    let mut n = 0usize;
    for &x in a {
        match b[lo..].binary_search(&x) {
            Ok(pos) => {
                n += 1;
                lo += pos + 1;
            }
            Err(pos) => lo += pos,
        }
        if lo >= b.len() {
            break;
        }
    }
    n
}

/// Galloping intersection appended to `out` (`a` is the short list).
fn gallop_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    let mut lo = 0usize;
    for &x in a {
        match b[lo..].binary_search(&x) {
            Ok(pos) => {
                out.push(x);
                lo += pos + 1;
            }
            Err(pos) => lo += pos,
        }
        if lo >= b.len() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
        a.iter().copied().filter(|x| b.contains(x)).collect()
    }

    #[test]
    fn empty_disjoint_identical() {
        let a: Vec<u32> = vec![1, 3, 5];
        let empty: Vec<u32> = vec![];
        assert_eq!(intersect_count(&a, &empty), 0);
        assert_eq!(intersect_count(&empty, &a), 0);
        assert_eq!(intersect_count(&empty, &empty), 0);
        let b: Vec<u32> = vec![2, 4, 6];
        assert_eq!(intersect_count(&a, &b), 0);
        assert_eq!(intersect_count(&a, &a), 3);
        let mut out = Vec::new();
        intersect_into(&a, &a, &mut out);
        assert_eq!(out, a);
    }

    #[test]
    fn gallop_matches_merge_on_skewed_lists() {
        let long: Vec<u32> = (0..2000).step_by(3).collect();
        let short: Vec<u32> = vec![0, 3, 4, 600, 601, 1998];
        assert!(skewed(short.len(), long.len()));
        let want = naive_intersect(&short, &long);
        assert_eq!(intersect_count(&short, &long), want.len());
        assert_eq!(intersect_count(&long, &short), want.len());
        let mut out = Vec::new();
        intersect_into(&short, &long, &mut out);
        assert_eq!(out, want);
        out.clear();
        intersect_into(&long, &short, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn bounded_below_all_and_above_all() {
        let a: Vec<u32> = vec![10, 20, 30];
        let b: Vec<u32> = vec![10, 25, 30];
        // bound below every element: empty result
        assert_eq!(intersect_count_below(&a, &b, 5), 0);
        let mut out = Vec::new();
        intersect_into_below(&a, &b, 5, &mut out);
        assert!(out.is_empty());
        // bound above every element: same as unbounded
        assert_eq!(intersect_count_below(&a, &b, 1000), 2);
        intersect_into_below(&a, &b, 1000, &mut out);
        assert_eq!(out, vec![10, 30]);
        // bound is exclusive
        assert_eq!(intersect_count_below(&a, &b, 30), 1);
        out.clear();
        intersect_into_below(&a, &b, 30, &mut out);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn difference_edge_cases() {
        let a: Vec<u32> = vec![1, 2, 3, 4];
        let empty: Vec<u32> = vec![];
        let mut out = Vec::new();
        difference_into(&a, &empty, &mut out);
        assert_eq!(out, a);
        out.clear();
        difference_into(&empty, &a, &mut out);
        assert!(out.is_empty());
        out.clear();
        difference_into(&a, &a, &mut out);
        assert!(out.is_empty());
        out.clear();
        difference_into(&a, &[2, 4], &mut out);
        assert_eq!(out, vec![1, 3]);
    }

    #[test]
    fn difference_gallop_matches_merge() {
        let long: Vec<u32> = (0..3000).step_by(2).collect();
        let short: Vec<u32> = vec![0, 1, 100, 101, 2998, 2999, 5000];
        assert!(skewed(short.len(), long.len()));
        let mut got = Vec::new();
        difference_into(&short, &long, &mut got);
        let want: Vec<u32> =
            short.iter().copied().filter(|x| !long.contains(x)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn bitset_filters_match_list_kernels() {
        let a: Vec<u32> = vec![0, 5, 63, 64, 65, 199];
        let b: Vec<u32> = vec![5, 64, 199, 200];
        let mut bits = BitSet::new(256);
        for &x in &b {
            bits.insert(x as usize);
        }
        assert_eq!(intersect_bitset_count(&a, &bits), intersect_count(&a, &b));
        let mut keep = a.clone();
        retain_in_bitset(&mut keep, &bits);
        assert_eq!(keep, naive_intersect(&a, &b));
        let mut drop = a.clone();
        retain_not_in_bitset(&mut drop, &bits);
        let mut want = Vec::new();
        difference_into(&a, &b, &mut want);
        assert_eq!(drop, want);
    }

    #[test]
    fn word_parallel_count() {
        let mut x = BitSet::new(300);
        let mut y = BitSet::new(300);
        for i in [1usize, 64, 65, 130, 299] {
            x.insert(i);
        }
        for i in [1usize, 65, 131, 299] {
            y.insert(i);
        }
        assert_eq!(intersect_words_count(x.words(), y.words()), 3);
        assert_eq!(intersect_words_count(x.words(), x.words()), 5);
        assert_eq!(intersect_words_count(&[], y.words()), 0);
    }

    #[test]
    fn count_less_than_bounds() {
        let a = vec![1u32, 3, 5, 7];
        assert_eq!(count_less_than(&a, 0), 0);
        assert_eq!(count_less_than(&a, 4), 2);
        assert_eq!(count_less_than(&a, 100), 4);
    }
}
