//! Synthetic graph generators.
//!
//! The paper evaluates on SNAP/web-crawl graphs (LiveJournal, Orkut,
//! Twitter40, Friendster, UK2007, Gsh) and labeled graphs (Patents,
//! Youtube, ProteinDB). Those are multi-GB downloads we do not have, so
//! the dataset registry (`coordinator::datasets`) maps each to a seeded
//! synthetic stand-in generated here (DESIGN.md §4 records the
//! substitution). RMAT reproduces the heavy-tailed degree skew that
//! drives GPM search-space behaviour; Erdős–Rényi provides a low-skew
//! contrast; ring/grid give degenerate shapes for tests.

use super::builder::GraphBuilder;
use super::csr::{CsrGraph, VertexId};
use crate::util::rng::Rng;

/// Erdős–Rényi G(n, p). If `label_pool` is non-empty, labels are drawn
/// uniformly from it.
pub fn erdos_renyi(n: usize, p: f64, seed: u64, label_pool: &[u32]) -> CsrGraph {
    let mut rng = Rng::seeded(seed);
    let mut b = GraphBuilder::new(n);
    for u in 0..n as VertexId {
        for v in (u + 1)..n as VertexId {
            if rng.chance(p) {
                b.add_edge(u, v);
            }
        }
    }
    finish(b, n, &mut rng, label_pool)
}

/// RMAT power-law generator (Chakrabarti et al.), the standard synthetic
/// stand-in for social/web graphs. `scale` = log2(n); `edge_factor` =
/// average degree / 2.
pub fn rmat(scale: u32, edge_factor: usize, seed: u64, label_pool: &[u32]) -> CsrGraph {
    // Graph500-style parameters produce realistic skew.
    rmat_with(scale, edge_factor, 0.57, 0.19, 0.19, seed, label_pool)
}

/// RMAT with explicit quadrant probabilities (`a`, `b`, `c`; `d` implied).
pub fn rmat_with(
    scale: u32,
    edge_factor: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
    label_pool: &[u32],
) -> CsrGraph {
    let n = 1usize << scale;
    let m = n * edge_factor;
    let mut rng = Rng::seeded(seed);
    let mut builder = GraphBuilder::new(n);
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        if u != v {
            builder.add_edge(u as VertexId, v as VertexId);
        }
    }
    finish(builder, n, &mut rng, label_pool)
}

/// Ring of n vertices (each degree 2): zero triangles, useful for
/// boundary tests.
pub fn ring(n: usize) -> CsrGraph {
    let mut b = GraphBuilder::new(n);
    for u in 0..n as VertexId {
        b.add_edge(u, ((u as usize + 1) % n) as VertexId);
    }
    b.build()
}

/// Adversarially skewed two-hub graph: vertices 0 and 1 are adjacent to
/// everything (and to each other), the remaining `n - 2` leaves form a
/// ring among themselves. Degrees are `n-1, n-1, 4, 4, ...` — the
/// worst case for per-root load balance, which is exactly what the
/// scheduler regression tests need: almost all mining work sits under
/// two root tasks, so a run only finishes promptly if the level-1
/// candidate sets of the hubs get split across workers
/// (`rust/tests/sched_invariance.rs`, the `pr4-sched-*` bench
/// sections). Requires `n >= 5` so the leaf ring is simple.
pub fn two_hub(n: usize) -> CsrGraph {
    assert!(n >= 5, "two_hub needs at least 3 ring leaves");
    let mut b = GraphBuilder::new(n);
    b.add_edge(0, 1);
    for v in 2..n as VertexId {
        b.add_edge(0, v);
        b.add_edge(1, v);
        let w = if (v as usize) + 1 < n { v + 1 } else { 2 };
        b.add_edge(v, w);
    }
    b.build()
}

/// Complete graph K_n: C(n,3) triangles, C(n,k) k-cliques.
pub fn complete(n: usize) -> CsrGraph {
    let mut b = GraphBuilder::new(n);
    for u in 0..n as VertexId {
        for v in (u + 1)..n as VertexId {
            b.add_edge(u, v);
        }
    }
    b.build()
}

/// Barabási–Albert preferential attachment with `m` edges per new vertex.
/// Produces power-law degrees plus guaranteed connectivity.
pub fn barabasi_albert(n: usize, m: usize, seed: u64, label_pool: &[u32]) -> CsrGraph {
    assert!(n > m && m >= 1);
    let mut rng = Rng::seeded(seed);
    let mut b = GraphBuilder::new(n);
    // endpoint pool: vertices appear proportionally to degree
    let mut pool: Vec<VertexId> = Vec::with_capacity(2 * n * m);
    for u in 0..m as VertexId {
        for v in (u + 1)..=(m as VertexId) {
            b.add_edge(u, v);
            pool.push(u);
            pool.push(v);
        }
    }
    for u in (m + 1)..n {
        let mut targets: Vec<VertexId> = Vec::with_capacity(m);
        while targets.len() < m {
            let t = pool[rng.below(pool.len() as u64) as usize];
            if t != u as VertexId && !targets.contains(&t) {
                targets.push(t);
            }
        }
        for &t in &targets {
            b.add_edge(u as VertexId, t);
            pool.push(u as VertexId);
            pool.push(t);
        }
    }
    finish(b, n, &mut rng, label_pool)
}

fn finish(b: GraphBuilder, n: usize, rng: &mut Rng, label_pool: &[u32]) -> CsrGraph {
    if label_pool.is_empty() {
        b.build()
    } else {
        let labels = (0..n)
            .map(|_| label_pool[rng.below(label_pool.len() as u64) as usize])
            .collect();
        b.with_labels(labels).build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_edge_count_plausible() {
        let g = erdos_renyi(100, 0.1, 1, &[]);
        let expected = 0.1 * 100.0 * 99.0 / 2.0;
        let m = g.num_undirected_edges() as f64;
        assert!((expected * 0.6..expected * 1.4).contains(&m), "m={m}");
    }

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(10, 8, 2, &[]);
        assert!(g.num_vertices() == 1024);
        // power-law: max degree should far exceed the average
        let avg = g.num_directed_edges() as f64 / g.num_vertices() as f64;
        assert!(g.max_degree() as f64 > 4.0 * avg, "max={} avg={avg}", g.max_degree());
    }

    #[test]
    fn generators_deterministic() {
        let a = rmat(8, 8, 42, &[]);
        let b = rmat(8, 8, 42, &[]);
        assert_eq!(a.neighbors, b.neighbors);
        let c = rmat(8, 8, 43, &[]);
        assert_ne!(a.neighbors, c.neighbors);
    }

    #[test]
    fn ring_has_no_triangles() {
        let g = ring(10);
        assert_eq!(g.num_undirected_edges(), 10);
        assert!(g.edges().all(|(u, v)| g.intersect_count(u, v) == 0));
    }

    #[test]
    fn complete_graph_degrees() {
        let g = complete(6);
        assert_eq!(g.num_undirected_edges(), 15);
        assert!((0..6).all(|v| g.degree(v) == 5));
    }

    #[test]
    fn two_hub_shape() {
        let n = 64usize;
        let g = two_hub(n);
        assert_eq!(g.num_vertices(), n);
        assert_eq!(g.degree(0), (n - 1) as usize);
        assert_eq!(g.degree(1), (n - 1) as usize);
        // leaves: both hubs + two ring neighbors
        assert!((2..n as u32).all(|v| g.degree(v) == 4));
    }

    #[test]
    fn ba_connected_min_degree() {
        let g = barabasi_albert(200, 3, 5, &[]);
        assert!((0..200u32).all(|v| g.degree(v) >= 3));
    }

    #[test]
    fn labels_drawn_from_pool() {
        let g = erdos_renyi(50, 0.2, 3, &[2, 5, 9]);
        assert!(g.is_labeled());
        assert!(g.labels.iter().all(|l| [2, 5, 9].contains(l)));
    }
}
