//! Quickstart: the Sandslash high-level API in ~30 lines.
//!
//! A GPM problem is a *specification* (paper Table 1): three flags plus
//! patterns. Sandslash picks the search strategy, data structures and
//! optimizations (§4.3). Run with:
//!
//!     cargo run --release --example quickstart

use sandslash::apps::{solve, MiningOutput};
use sandslash::engine::{MinerConfig, OptFlags, ProblemSpec};
use sandslash::graph::gen;

fn main() {
    // A power-law graph standing in for a small social network.
    let g = gen::rmat(12, 8, 42, &[]);
    println!(
        "graph: {} vertices, {} edges",
        g.num_vertices(),
        g.num_undirected_edges()
    );
    let cfg = MinerConfig::new(OptFlags::hi());

    // Triangle counting: vertex-induced, counting, explicit pattern.
    if let MiningOutput::Count(c) = solve(&g, &ProblemSpec::tc(), &cfg) {
        println!("triangles: {c}");
    }

    // 4-clique listing — same spec shape, bigger pattern.
    if let MiningOutput::Count(c) = solve(&g, &ProblemSpec::clique_listing(4), &cfg) {
        println!("4-cliques: {c}");
    }

    // 3-motif counting: implicit patterns, classified automatically.
    if let MiningOutput::PerPattern(rows) = solve(&g, &ProblemSpec::motif_counting(3), &cfg) {
        for (name, count) in rows {
            println!("3-motif {name}: {count}");
        }
    }

    // Subgraph listing of an explicit edge-induced pattern.
    let spec = ProblemSpec::subgraph_listing(sandslash::pattern::library::diamond());
    if let MiningOutput::Count(c) = solve(&g, &spec, &cfg) {
        println!("diamonds (edge-induced embeddings): {c}");
    }

    // Flip one flag set to get the low-level optimized path (LC/LG).
    let lo = MinerConfig::new(OptFlags::lo());
    if let MiningOutput::Count(c) = solve(&g, &ProblemSpec::clique_listing(5), &lo) {
        println!("5-cliques (Sandslash-Lo, local graphs): {c}");
    }
}
