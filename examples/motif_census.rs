//! Motif census as a graph "signature" (paper §1: motif counts differ
//! across domains and identify a graph's probable origin [20]).
//!
//! Counts 3- and 4-motifs for three synthetic families — power-law
//! (social-like), Erdős–Rényi (random), and preferential attachment —
//! and prints the normalized signatures side by side, computed with the
//! Lo (formula-based local counting) path.
//!
//!     cargo run --release --example motif_census

use sandslash::apps::motif::{motif3_lo, motif4_lo};
use sandslash::engine::{MinerConfig, OptFlags};
use sandslash::graph::{gen, CsrGraph};
use sandslash::pattern::library::{MOTIF3_NAMES, MOTIF4_NAMES};
use sandslash::util::timer::timed;

fn census(name: &str, g: &CsrGraph) -> (String, Vec<f64>) {
    let cfg = MinerConfig::new(OptFlags::lo());
    let ((m3, m4), secs) = timed(|| (motif3_lo(g, &cfg), motif4_lo(g, &cfg)));
    let all: Vec<u64> = m3.into_iter().chain(m4).collect();
    let total: f64 = all.iter().map(|&x| x as f64).sum::<f64>().max(1.0);
    println!(
        "{name}: |V|={} |E|={} censused in {}",
        g.num_vertices(),
        g.num_undirected_edges(),
        sandslash::util::timer::fmt_secs(secs)
    );
    (name.to_string(), all.iter().map(|&x| x as f64 / total).collect())
}

fn main() {
    let families = [
        ("rmat (social-like)", gen::rmat(12, 8, 1, &[])),
        ("erdos-renyi", gen::erdos_renyi(4096, 0.004, 2, &[])),
        ("pref-attach", gen::barabasi_albert(4096, 8, 3, &[])),
    ];
    let censuses: Vec<(String, Vec<f64>)> =
        families.iter().map(|(n, g)| census(n, g)).collect();

    let names: Vec<&str> = MOTIF3_NAMES.iter().chain(MOTIF4_NAMES.iter()).copied().collect();
    println!("\n{:>18} {:>20} {:>20} {:>20}", "motif", censuses[0].0, censuses[1].0, censuses[2].0);
    for (i, motif) in names.iter().enumerate() {
        println!(
            "{:>18} {:>20.6} {:>20.6} {:>20.6}",
            motif, censuses[0].1[i], censuses[1].1[i], censuses[2].1[i]
        );
    }
    println!("\nSignatures differ by family — triangle-rich motifs dominate the");
    println!("clustered families while ER mass sits on wedges/paths, which is");
    println!("exactly how motif censuses fingerprint a graph's origin.");
}
