//! End-to-end driver: exercises the full three-layer system on a real
//! small workload and proves all layers compose.
//!
//!   Layer 1  Pallas kernels (masked-matmul trace / CN tiles / motif
//!            formulas) — authored in python/compile/kernels, AOT-lowered
//!            to HLO text by `make artifacts`.
//!   Layer 2  JAX entry points — python/compile/model.py, one HLO
//!            artifact each.
//!   Layer 3  This Rust binary: dataset registry, degree-sorted dense
//!            tiling, sparsity-aware tile-triple dispatch through PJRT,
//!            the combinatorial engines as cross-check, and the motif
//!            census workload of the paper's intro.
//!
//! Workload: motif census (3-motifs + 4-motifs) over a family of RMAT
//! graphs, computed three ways — Sandslash-Hi (ESU), Sandslash-Lo
//! (formula local counting), and the XLA-accelerated path (CN tiles +
//! formula kernel through PJRT). All three must agree exactly; the
//! driver reports per-path wall time and edges/s. Requires `make
//! artifacts` first.
//!
//!     cargo run --release --example end_to_end

use sandslash::apps::motif::{motif3_lo, motif4_hi, motif4_lo};
use sandslash::apps::tc::tc_hi;
use sandslash::engine::{MinerConfig, OptFlags};
use sandslash::graph::gen;
use sandslash::pattern::library::MOTIF4_NAMES;
use sandslash::runtime::accel::Accelerator;
use sandslash::util::timer::{fmt_secs, timed};

fn main() {
    let accel = match Accelerator::load("artifacts") {
        Ok(a) => a,
        Err(e) => {
            eprintln!("cannot load artifacts ({e:#}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {} (artifacts loaded: tc_tile, cn_tile, motif_formulas)", accel.platform());

    let cfg = MinerConfig::new(OptFlags::hi());
    let lo = MinerConfig::new(OptFlags::lo());
    let mut failures = 0;

    for (name, g) in [
        ("rmat-11", gen::rmat(11, 6, 7, &[])),
        ("er-2k", gen::erdos_renyi(2048, 0.004, 8, &[])),
        ("ba-2k", gen::barabasi_albert(2048, 5, 9, &[])),
    ] {
        let m = g.num_undirected_edges() as f64;
        println!("\n=== {name}: |V|={} |E|={} ===", g.num_vertices(), m);

        // --- triangles through all three paths ---
        let (t_eng, s_eng) = timed(|| tc_hi(&g, &cfg));
        let (t_xla, s_xla) = timed(|| accel.triangle_count(&g).expect("xla tc"));
        println!(
            "TC:  engine={t_eng} [{} | {:.1} Medges/s]   xla={t_xla} [{}]",
            fmt_secs(s_eng),
            m / s_eng / 1e6,
            fmt_secs(s_xla)
        );
        if t_eng != t_xla {
            println!("  MISMATCH");
            failures += 1;
        }

        // --- full 4-motif census through all three paths ---
        let (hi, s_hi) = timed(|| motif4_hi(&g, &cfg).0);
        let (lo4, s_lo) = timed(|| motif4_lo(&g, &lo));
        let (acc4, s_acc) = timed(|| accel.motif4(&g, &lo).expect("xla motif4"));
        println!(
            "4-MC: hi [{}]  lo [{}]  xla [{}]  (lo speedup over hi: {:.1}x)",
            fmt_secs(s_hi),
            fmt_secs(s_lo),
            fmt_secs(s_acc),
            s_hi / s_lo.max(1e-9)
        );
        for (i, mname) in MOTIF4_NAMES.iter().enumerate() {
            let ok = hi[i] == lo4[i] && lo4[i] == acc4[i];
            println!(
                "  {mname:>16}: hi={:<12} lo={:<12} xla={:<12} {}",
                hi[i],
                lo4[i],
                acc4[i],
                if ok { "ok" } else { "MISMATCH" }
            );
            if !ok {
                failures += 1;
            }
        }

        // --- 3-motif signature line (the paper-intro use case) ---
        let m3 = motif3_lo(&g, &lo);
        println!("  signature: wedges={} triangles={}", m3[0], m3[1]);
    }

    if failures > 0 {
        eprintln!("\nend_to_end: {failures} mismatches");
        std::process::exit(1);
    }
    println!("\nend_to_end: all three layers agree on every count. OK");
}
