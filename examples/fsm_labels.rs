//! Frequent subgraph mining on a labeled graph (the paper's k-FSM
//! application, Table 1 right column): find all edge-induced patterns
//! with MNI domain support above a threshold.
//!
//!     cargo run --release --example fsm_labels

use sandslash::apps::fsm_app;
use sandslash::coordinator::datasets;
use sandslash::engine::{MinerConfig, OptFlags};

fn main() {
    let g = datasets::load("pa-tiny").expect("dataset");
    println!(
        "pa-tiny: |V|={} |E|={} labels={}",
        g.num_vertices(),
        g.num_undirected_edges(),
        g.num_labels()
    );
    let cfg = MinerConfig::new(OptFlags::hi());

    for sigma in [2u64, 5, 10] {
        let (r, secs) = sandslash::util::timer::timed(|| fsm_app::fsm(&g, 3, sigma, &cfg));
        println!(
            "\nsigma > {sigma}: {} frequent patterns (k <= 3 edges) in {}",
            r.frequent.len(),
            sandslash::util::timer::fmt_secs(secs)
        );
        for f in r.frequent.iter().take(8) {
            let labels: Vec<u32> =
                (0..f.pattern.num_vertices()).map(|v| f.pattern.label(v)).collect();
            println!(
                "  {} labels{:?}  support={}  embeddings={}",
                f.pattern, labels, f.support, f.embeddings
            );
        }
        if r.frequent.len() > 8 {
            println!("  ... and {} more", r.frequent.len() - 8);
        }
    }
    println!("\nAnti-monotone MNI pruning means raising sigma shrinks the result");
    println!("monotonically without re-exploring pruned sub-pattern subtrees.");
}
