//! Large-pattern mining (paper Fig. 11): k-cliques for k = 4..9.
//!
//! Demonstrates why the low-level API exists: at large k the high-level
//! path's global intersections grow, while the LG (local graph)
//! optimization — expressed in the paper as ~30 lines of `initLG` /
//! `updateLG` user code (Listing 4) — keeps the search inside a
//! degeneracy-bounded neighborhood graph that shrinks at every level.
//!
//!     cargo run --release --example large_cliques

use sandslash::apps::clique::{clique_hi, clique_lo};
use sandslash::coordinator::datasets;
use sandslash::engine::{MinerConfig, OptFlags};
use sandslash::util::timer::{fmt_secs, timed};

fn main() {
    let g = datasets::load("fr-tiny").expect("dataset");
    println!(
        "fr-tiny: |V|={} |E|={} degeneracy={}",
        g.num_vertices(),
        g.num_undirected_edges(),
        sandslash::graph::orientation::degeneracy(&g)
    );
    let cfg = MinerConfig::new(OptFlags::hi());
    let lo_cfg = MinerConfig::new(OptFlags::lo());

    println!("\n{:>3} {:>16} {:>12} {:>12} {:>8}", "k", "cliques", "hi", "lo (LG)", "speedup");
    for k in 4..=9 {
        let (hi, t_hi) = timed(|| clique_hi(&g, k, &cfg).0);
        let (lo, t_lo) = timed(|| clique_lo(&g, k, &lo_cfg).0);
        assert_eq!(hi, lo);
        println!(
            "{:>3} {:>16} {:>12} {:>12} {:>7.2}x",
            k,
            hi,
            fmt_secs(t_hi),
            fmt_secs(t_lo),
            t_hi / t_lo.max(1e-9)
        );
        if hi == 0 {
            println!("  (no {k}-cliques; stopping)");
            break;
        }
    }
}
