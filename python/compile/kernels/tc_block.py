"""Layer-1 Pallas kernels for dense-tile graph pattern counting.

The combinatorial Sandslash engine (Layer 3, Rust) mines patterns by
subgraph-tree exploration.  For *counting* problems on dense regions of the
adjacency matrix, a linear-algebra formulation is far more
hardware-friendly (cf. KokkosKernels LA-based triangle counting, ref [57]
of the paper): with an oriented (DAG) adjacency matrix U,

    #triangles = sum( (U @ U) * U )          (elementwise mask, no /6)

and per-edge common-neighbour counts (used by the paper's Local Counting
optimization, Section 5 / Listing 3) are

    CN = (A @ A) * A        (CN[u,v] = #triangles through edge (u,v))

Both are tile-decomposable: the Rust coordinator streams [B,B] blocks of
the adjacency matrix and accumulates scalar / tile partial results, which
lets it skip all-zero tiles (sparsity-aware tiling).

TPU adaptation (DESIGN.md "Hardware Adaptation"): the paper's CPU
hand-optimized baselines (GAP, PGD) count via sorted-list intersection; on
a matrix unit the same reduction is a masked matmul.  We tile for VMEM
with a K-blocked BlockSpec so each grid step holds three tiles in VMEM and
drives the MXU with a [B,BK]x[BK,B] contraction.  Pallas runs under
interpret=True here (CPU PJRT cannot execute Mosaic custom-calls); the
BlockSpec structure is what a real TPU lowering would use.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# masked matmul trace:  out = sum((x @ y) * m)
# ---------------------------------------------------------------------------

def _mmt_kernel(x_ref, y_ref, m_ref, o_ref):
    """Grid = (K/BK,): accumulate sum((x_blk @ y_blk) * m) over K blocks.

    The mask multiply distributes over the K-sum:
        sum_ij m_ij * sum_k x_ik y_kj = sum_k sum_ij m_ij * (x_:k @ y_k:)_ij
    so each K-step masks + reduces its own partial product.  All grid steps
    map to the same output block; Pallas' sequential-revisit semantics turn
    o_ref into the running accumulator (no extra VMEM scratch needed).
    """
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[0] = jnp.float32(0.0)

    part = jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)
    o_ref[0] += jnp.sum(part * m_ref[...])


def masked_matmul_trace(x, y, m, *, block_k=None):
    """sum((x @ y) * m) via a Pallas kernel with a K-blocked schedule.

    x: [B, K], y: [K, B], m: [B, B] (f32 0/1 mask).  Returns f32[1].
    """
    b, kdim = x.shape
    bk = block_k or kdim
    assert kdim % bk == 0, "block_k must divide K"
    steps = kdim // bk
    return pl.pallas_call(
        _mmt_kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((b, bk), lambda k: (0, k)),
            pl.BlockSpec((bk, b), lambda k: (k, 0)),
            pl.BlockSpec((b, b), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda k: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(x, y, m)


# ---------------------------------------------------------------------------
# masked matmul tile:  out = (x @ y) * m    (per-edge common-neighbour counts)
# ---------------------------------------------------------------------------

def _mmm_kernel(x_ref, y_ref, m_ref, o_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ) * m_ref[...]


def masked_matmul_tile(x, y, m, *, block_k=None):
    """(x @ y) * m via a Pallas kernel.  Returns f32[B, B].

    With x = y = m = adjacency tile row/col blocks, out[u, v] is the number
    of common neighbours of u and v restricted to the K range — i.e. the
    per-edge local triangle count tile used by formula-based local counting
    (paper Section 5, Fig. 6).
    """
    b, kdim = x.shape
    bk = block_k or kdim
    assert kdim % bk == 0, "block_k must divide K"
    steps = kdim // bk
    return pl.pallas_call(
        _mmm_kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((b, bk), lambda k: (0, k)),
            pl.BlockSpec((bk, b), lambda k: (k, 0)),
            pl.BlockSpec((b, b), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, b), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, b), jnp.float32),
        interpret=True,
    )(x, y, m)


# ---------------------------------------------------------------------------
# motif formula kernel: 4-motif local counts from edge statistics
# ---------------------------------------------------------------------------

def _motif_kernel(tri_ref, du_ref, dv_ref, valid_ref, o_ref):
    """Vectorized Listing-3 formulas (paper Appendix A), one lane per edge.

    Inputs per edge e=(u,v): local triangle count tri, degrees du/dv and a
    validity mask (padding lanes contribute 0).  Outputs, stacked on the
    leading axis: [diamond, tailed_triangle, path4, star3, wedge] local
    counts.  Diamond uses C(tri,2); wedge uses Eq. (1) of the paper.
    """
    tri = tri_ref[...]
    du = du_ref[...]
    dv = dv_ref[...]
    valid = valid_ref[...]
    staru = du - tri - 1.0
    starv = dv - tri - 1.0
    diamond = tri * (tri - 1.0) * 0.5
    tailed = tri * (staru + starv)
    path4 = staru * starv
    star3 = 0.5 * (staru * (staru - 1.0) + starv * (starv - 1.0))
    wedge = staru + starv
    o_ref[0, :] = diamond * valid
    o_ref[1, :] = tailed * valid
    o_ref[2, :] = path4 * valid
    o_ref[3, :] = star3 * valid
    o_ref[4, :] = wedge * valid


def motif_local_counts(tri, deg_u, deg_v, valid):
    """Per-edge 4-motif local counts.  All inputs f32[L]; returns f32[5, L]."""
    (l,) = tri.shape
    return pl.pallas_call(
        _motif_kernel,
        out_shape=jax.ShapeDtypeStruct((5, l), jnp.float32),
        interpret=True,
    )(tri, deg_u, deg_v, valid)
