"""Pure-jnp oracles for the Pallas kernels in tc_block.py.

These are the correctness reference: pytest (python/tests/) asserts the
Pallas kernels match these to float tolerance over hypothesis-generated
inputs, and the Rust integration tests check the AOT artifacts reproduce
the same numbers end-to-end through PJRT.
"""

import jax.numpy as jnp


def masked_matmul_trace(x, y, m):
    """sum((x @ y) * m), scalar f32 (as shape [1] to match the kernel)."""
    return jnp.sum(jnp.dot(x, y) * m).reshape((1,))


def masked_matmul_tile(x, y, m):
    """(x @ y) * m elementwise."""
    return jnp.dot(x, y) * m


def motif_local_counts(tri, deg_u, deg_v, valid):
    """Stacked per-edge 4-motif local counts; see _motif_kernel."""
    staru = deg_u - tri - 1.0
    starv = deg_v - tri - 1.0
    diamond = tri * (tri - 1.0) * 0.5
    tailed = tri * (staru + starv)
    path4 = staru * starv
    star3 = 0.5 * (staru * (staru - 1.0) + starv * (starv - 1.0))
    wedge = staru + starv
    return jnp.stack(
        [diamond * valid, tailed * valid, path4 * valid, star3 * valid,
         wedge * valid]
    )


def triangle_count_dense(adj_oriented):
    """Reference triangle count from a dense oriented adjacency matrix."""
    u = adj_oriented.astype(jnp.float32)
    return jnp.sum(jnp.dot(u, u) * u)
