"""AOT lowering: JAX (Layer 2) -> HLO text artifacts for the Rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` rust crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Writes one ``<name>.hlo.txt`` per entry point plus ``manifest.txt``
recording shapes so the Rust side can validate its buffers.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

ENTRY_POINTS = {
    "tc_tile": (model.tc_tile, model.tc_tile_spec),
    "cn_tile": (model.cn_tile, model.cn_tile_spec),
    "motif_formulas": (model.motif_formulas, model.motif_formulas_spec),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str) -> str:
    fn, spec_fn = ENTRY_POINTS[name]
    return to_hlo_text(jax.jit(fn).lower(*spec_fn()))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", choices=sorted(ENTRY_POINTS), default=None)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = [args.only] if args.only else sorted(ENTRY_POINTS)
    manifest = [
        f"tile={model.TILE}",
        f"block_k={model.BLOCK_K}",
        f"edge_lanes={model.EDGE_LANES}",
    ]
    for name in names:
        text = lower_entry(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        _, spec_fn = ENTRY_POINTS[name]
        shapes = ";".join(
            f"{s.dtype}{list(s.shape)}" for s in spec_fn()
        )
        manifest.append(f"{name}: {shapes}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
