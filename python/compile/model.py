"""Layer-2 JAX compute graphs composed from the Layer-1 Pallas kernels.

Each public function here is a fixed-shape jittable computation that
``aot.py`` lowers to HLO text once at build time.  The Rust coordinator
(rust/src/runtime) loads the resulting artifacts and calls them on the hot
path; Python is never imported at runtime.

Tile size: TILE = 128 matches the TPU MXU systolic array edge (128x128)
and keeps the three-tile working set (3 * 128^2 * 4B = 192 KiB) far inside
a TensorCore's ~16 MiB VMEM, leaving room for double-buffered HBM->VMEM
prefetch of the next (x, y) tile pair.  EDGE_LANES = 4096 is a whole
number of 8x128 vregs for the elementwise motif formula kernel.
"""

import jax
import jax.numpy as jnp

from compile.kernels import tc_block

TILE = 128
BLOCK_K = 128
EDGE_LANES = 4096


def tc_tile(x, y, m):
    """Scalar triangle contribution of one (x, y, m) tile triple.

    With U the DAG-oriented adjacency matrix split into TILE x TILE blocks,
    sum over all (i, k, j) of tc_tile(U[i,k], U[k,j], U[i,j]) equals the
    exact triangle count of the graph (no over-count correction needed).
    """
    return (tc_block.masked_matmul_trace(x, y, m, block_k=BLOCK_K),)


def cn_tile(x, y, m):
    """Per-edge common-neighbour count tile: (x @ y) * m.

    Accumulated over k-blocks by the Rust caller to produce per-edge local
    triangle counts for formula-based Local Counting (paper Section 5).
    """
    return (tc_block.masked_matmul_tile(x, y, m, block_k=BLOCK_K),)


def motif_formulas(tri, deg_u, deg_v, valid):
    """Batched 4-motif local counts from per-edge statistics.

    Inputs are f32[EDGE_LANES] (padded; `valid` zeroes the padding).
    Output f32[5, EDGE_LANES]: diamond / tailed-triangle / 4-path / 3-star
    / wedge local counts per edge (Listing 3 of the paper, vectorized).
    """
    return (tc_block.motif_local_counts(tri, deg_u, deg_v, valid),)


def tc_tile_spec():
    t = jax.ShapeDtypeStruct((TILE, TILE), jnp.float32)
    return (t, t, t)


def cn_tile_spec():
    return tc_tile_spec()


def motif_formulas_spec():
    v = jax.ShapeDtypeStruct((EDGE_LANES,), jnp.float32)
    return (v, v, v, v)
