"""Pallas kernels vs pure-jnp oracle (ref.py) — the core L1 correctness
signal.  Hypothesis sweeps shapes, block sizes and value distributions."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, tc_block

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rand01(rng, shape, density):
    return (rng.random(shape) < density).astype(np.float32)


@given(
    b=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([8, 16]),
    density=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_trace_matches_ref(b, k, bk, density, seed):
    rng = np.random.default_rng(seed)
    x = rand01(rng, (b, k), density)
    y = rand01(rng, (k, b), density)
    m = rand01(rng, (b, b), density)
    got = tc_block.masked_matmul_trace(x, y, m, block_k=bk)
    want = ref.masked_matmul_trace(x, y, m)
    np.testing.assert_allclose(got, want, rtol=1e-5)


@given(
    b=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([16, 32]),
    bk=st.sampled_from([8, 16]),
    density=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_tile_matches_ref(b, k, bk, density, seed):
    rng = np.random.default_rng(seed)
    x = rand01(rng, (b, k), density)
    y = rand01(rng, (k, b), density)
    m = rand01(rng, (b, b), density)
    got = tc_block.masked_matmul_tile(x, y, m, block_k=bk)
    want = ref.masked_matmul_tile(x, y, m)
    np.testing.assert_allclose(got, want, rtol=1e-5)


@given(
    n=st.sampled_from([128, 256, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_motif_formulas_match_ref(n, seed):
    rng = np.random.default_rng(seed)
    tri = rng.integers(0, 20, n).astype(np.float32)
    du = tri + rng.integers(1, 50, n).astype(np.float32)
    dv = tri + rng.integers(1, 50, n).astype(np.float32)
    valid = (rng.random(n) < 0.8).astype(np.float32)
    got = tc_block.motif_local_counts(tri, du, dv, valid)
    want = ref.motif_local_counts(tri, du, dv, valid)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_general_values_not_just_binary():
    """The kernels are general masked matmuls, not 0/1-only."""
    rng = np.random.default_rng(7)
    x = rng.standard_normal((16, 32)).astype(np.float32)
    y = rng.standard_normal((32, 16)).astype(np.float32)
    m = rng.standard_normal((16, 16)).astype(np.float32)
    np.testing.assert_allclose(
        tc_block.masked_matmul_trace(x, y, m, block_k=16),
        ref.masked_matmul_trace(x, y, m),
        rtol=1e-4,
    )


def test_block_k_must_divide():
    x = np.zeros((8, 24), np.float32)
    y = np.zeros((24, 8), np.float32)
    m = np.zeros((8, 8), np.float32)
    with pytest.raises(AssertionError):
        tc_block.masked_matmul_trace(x, y, m, block_k=16)


@given(seed=st.integers(0, 2**31 - 1))
def test_trace_is_triangle_count_on_oriented_adjacency(seed):
    """End-to-end semantic check: sum((U @ U) * U) counts triangles exactly
    when U is a DAG orientation of an undirected graph."""
    rng = np.random.default_rng(seed)
    n = 24
    a = rand01(rng, (n, n), 0.3)
    a = np.triu(np.maximum(a, a.T), k=1)  # oriented: strictly upper
    got = tc_block.masked_matmul_trace(a, a, a, block_k=8)[0]
    # brute force over vertex triples
    want = 0
    for i in range(n):
        for j in range(i + 1, n):
            if a[i, j]:
                want += int(np.sum(a[i, :] * a[j, :]))
    assert got == pytest.approx(want)
