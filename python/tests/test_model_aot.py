"""L2 model shape checks + AOT lowering round-trip sanity."""

import numpy as np
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def _tiles(seed=0, density=0.2):
    rng = np.random.default_rng(seed)
    t = model.TILE
    mk = lambda: (rng.random((t, t)) < density).astype(np.float32)
    return mk(), mk(), mk()


def test_tc_tile_shapes_and_value():
    x, y, m = _tiles()
    (out,) = model.tc_tile(x, y, m)
    assert out.shape == (1,)
    np.testing.assert_allclose(out, ref.masked_matmul_trace(x, y, m), rtol=1e-5)


def test_cn_tile_shapes_and_value():
    x, y, m = _tiles(seed=1)
    (out,) = model.cn_tile(x, y, m)
    assert out.shape == (model.TILE, model.TILE)
    np.testing.assert_allclose(out, ref.masked_matmul_tile(x, y, m), rtol=1e-5)


def test_motif_formulas_shape():
    l = model.EDGE_LANES
    z = jnp.zeros((l,), jnp.float32)
    (out,) = model.motif_formulas(z, z, z, z)
    assert out.shape == (5, l)


def test_all_entry_points_lower_to_hlo_text():
    for name in aot.ENTRY_POINTS:
        text = aot.lower_entry(name)
        assert text.startswith("HloModule"), name
        # entry layout mentions the right arity
        assert "entry_computation_layout" in text, name


def test_specs_match_entry_arity():
    for name, (fn, spec_fn) in aot.ENTRY_POINTS.items():
        specs = spec_fn()
        out = fn(*[jnp.zeros(s.shape, s.dtype) for s in specs])
        assert isinstance(out, tuple) and len(out) == 1, name
